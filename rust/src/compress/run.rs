//! The streaming compression session behind the pipeline API.
//!
//! [`CompressRun`] executes Algorithm 2 one block at a time behind an
//! iterator-style [`next_block`] loop, so callers observe progress and
//! own the pacing, and peak memory is bounded by one block's working set
//! plus the two activation streams — independent of model depth. The
//! monolithic [`compress_model`](super::pipeline::compress_model) is now
//! a thin wrapper that drives this session with in-memory options; the
//! CLI drives it with a checkpointed run directory instead.
//!
//! # Checkpoint protocol
//!
//! A checkpointed run keeps a directory with a versioned
//! [`RunManifest`] (`run.json`), one factor shard per block
//! (`block_<i>.aat`), and the latest activation-stream snapshot
//! (`state_<i>.aat` — the streams *entering* block `i`). After block `i`
//! finishes, commit proceeds in this order, each step atomic
//! (tmp + fsync + rename):
//!
//! 1. write the shard `block_<i>.aat`;
//! 2. write the snapshot `state_<i+1>.aat` (skipped after the last block);
//! 3. mark the block `written` in `run.json`, recording content hashes
//!    of both files;
//! 4. delete the now-obsolete `state_<i>.aat`.
//!
//! The manifest only ever references files that are already durable, so
//! a crash at any instant — kill -9 included — leaves a resumable
//! directory. Resume verifies every referenced file against its recorded
//! hash, restores the streams bit-exactly, and re-runs the loop from the
//! first unwritten block; because every parallel reduction in the solve
//! path merges in submission order, the resumed artifact is bitwise
//! identical to an uninterrupted run's, at any thread count.
//!
//! [`next_block`]: CompressRun::next_block

// aasvd-lint: allow-file(wallclock): per-stage timings feed the operator-facing CompressReport and progress lines only; no numeric result depends on them

use super::cov::CovTriple;
use super::pipeline::{
    concat_batches, embed_batches, solve_one, Collector, CompressReport, CompressedModel,
    Method, GROUPS,
};
use super::rank::Allocation;
use crate::data::TokenBatch;
use crate::model::lowrank::{exact_factors, BlockFactors};
use crate::model::quant_lowrank::{save_quant_blocks, QuantBlockFactors};
use crate::model::{Config, FlatStore};
use crate::refine::refine_block;
use crate::runtime::manifest::{BlockEntry, RunManifest};
use crate::util::hash::{fnv1a64, to_hex, Fnv64};
use crate::util::io::{ArchiveWriter, Tensor, TensorArchive};
use crate::util::pool::Pool;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Where a [`CompressRun`] persists its work, if anywhere.
#[derive(Clone, Debug)]
pub struct RunOptions {
    dir: Option<PathBuf>,
    artifact: Option<PathBuf>,
    resume: bool,
    keep_blocks: bool,
}

impl RunOptions {
    /// No disk at all: every block is kept in memory and the run ends
    /// with [`CompressRun::into_model`]. The historical `compress_model`
    /// behavior.
    pub fn in_memory() -> RunOptions {
        RunOptions {
            dir: None,
            artifact: None,
            resume: false,
            keep_blocks: true,
        }
    }

    /// Stream every block to a shard under `dir` and drop it from
    /// memory; `dir/run.json` checkpoints progress. The final artifact
    /// defaults to `dir/model.aat` (override with [`artifact`]).
    ///
    /// [`artifact`]: RunOptions::artifact
    pub fn checkpointed(dir: impl Into<PathBuf>) -> RunOptions {
        RunOptions {
            dir: Some(dir.into()),
            artifact: None,
            resume: false,
            keep_blocks: false,
        }
    }

    /// Where [`CompressRun::finish`] assembles the whole-model artifact.
    pub fn artifact(mut self, path: impl Into<PathBuf>) -> Self {
        self.artifact = Some(path.into());
        self
    }

    /// Continue an interrupted run from its last durable block instead
    /// of refusing to reuse the directory.
    pub fn resume(mut self) -> Self {
        self.resume = true;
        self
    }

    /// Keep solved blocks in memory even when checkpointing (needed for
    /// [`CompressRun::into_model`]; costs the memory bound).
    pub fn keep_blocks(mut self) -> Self {
        self.keep_blocks = true;
        self
    }
}

/// What one [`CompressRun::next_block`] call produced.
#[derive(Clone, Debug)]
pub struct BlockOutcome {
    pub index: usize,
    pub total: usize,
    /// wall seconds spent on this block (reporting only)
    pub secs: f64,
    /// the durable shard, when the run is checkpointed
    pub shard: Option<PathBuf>,
}

/// End-of-run accounting from [`CompressRun::finish`].
#[derive(Clone, Debug)]
pub struct CompressSummary {
    pub total: usize,
    /// blocks solved in this session
    pub solved: usize,
    /// blocks restored from a prior session's checkpoints
    pub resumed: usize,
    /// blocks skipped because the run was already complete
    pub skipped: usize,
    pub report: CompressReport,
    pub allocation: Allocation,
    pub artifact: Option<PathBuf>,
    pub artifact_hash: Option<u64>,
}

/// A streaming compression session: construct with [`new`], call
/// [`next_block`] until it returns `None`, then [`finish`] (artifact +
/// summary) or [`into_model`] (in-memory `CompressedModel`).
///
/// [`new`]: CompressRun::new
/// [`next_block`]: CompressRun::next_block
/// [`finish`]: CompressRun::finish
/// [`into_model`]: CompressRun::into_model
pub struct CompressRun<'a, C: Collector> {
    collector: &'a C,
    cfg: &'a Config,
    params: &'a FlatStore,
    method: &'a Method,
    allocation: Allocation,
    pool: Pool,
    dir: Option<PathBuf>,
    artifact: Option<PathBuf>,
    keep_blocks: bool,
    n_batches: usize,
    /// X — dense-network inputs to the next block
    xs: Vec<Vec<f32>>,
    /// X' — partially-compressed-network inputs (empty unless needed)
    xs_shift: Vec<Vec<f32>>,
    /// index of the next block to solve
    next: usize,
    report: CompressReport,
    quant_errs: Vec<f64>,
    /// blocks held in memory (all of them under `keep_blocks`)
    kept: Vec<BlockFactors>,
    manifest: Option<RunManifest>,
    resumed: usize,
    skipped: usize,
    solved: usize,
    artifact_hash: Option<u64>,
}

impl<'a, C: Collector> CompressRun<'a, C> {
    /// Open a session. `calib` batches must all be full
    /// (`real_rows == batch`). With checkpointed options this creates or
    /// (under `resume`) re-opens the run directory; with `resume`, every
    /// durable shard is hash-verified and the activation streams are
    /// restored bit-exactly before any new block is solved.
    pub fn new(
        collector: &'a C,
        cfg: &'a Config,
        params: &'a FlatStore,
        calib: &[TokenBatch],
        method: &'a Method,
        ratio: f64,
        options: RunOptions,
    ) -> Result<CompressRun<'a, C>> {
        ensure!(
            calib.iter().all(|b| b.real_rows == cfg.batch),
            "calibration batches must be full"
        );
        if method.refine_options().is_some() && collector.engine().is_none() {
            bail!(
                "method '{}' needs block refinement, which drives the AOT \
                 refine_step artifact — use an Engine-backed collector",
                method.name
            );
        }
        let allocation = Allocation::uniform(cfg, ratio, method.scheme());
        let pool = Pool::new(method.threads());
        let fingerprint = run_fingerprint(cfg, params, calib, method, ratio, &allocation);

        let RunOptions {
            dir,
            artifact,
            resume,
            keep_blocks,
        } = options;
        ensure!(
            dir.is_some() || !resume,
            "resume requires a checkpointed run directory"
        );
        let keep_blocks = keep_blocks || dir.is_none();
        let artifact = artifact.or_else(|| dir.as_ref().map(|d| d.join("model.aat")));

        let mut run = CompressRun {
            collector,
            cfg,
            params,
            method,
            allocation,
            pool,
            dir,
            artifact,
            keep_blocks,
            n_batches: calib.len(),
            xs: Vec::new(),
            xs_shift: Vec::new(),
            next: 0,
            report: CompressReport::default(),
            quant_errs: Vec::new(),
            kept: Vec::new(),
            manifest: None,
            resumed: 0,
            skipped: 0,
            solved: 0,
            artifact_hash: None,
        };

        if let Some(dir) = run.dir.clone() {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating run directory {}", dir.display()))?;
            let manifest_path = dir.join("run.json");
            if resume {
                if !manifest_path.exists() {
                    bail!(
                        "no run manifest at {} — nothing to resume; start a \
                         fresh run without the resume option",
                        manifest_path.display()
                    );
                }
                let manifest = RunManifest::load(&manifest_path)?;
                run.open_existing(&dir, manifest, fingerprint, ratio)?;
            } else {
                if manifest_path.exists() {
                    bail!(
                        "run directory {} already holds a run.json — pass \
                         resume to continue the interrupted run, or remove \
                         the directory to start over",
                        dir.display()
                    );
                }
                let manifest =
                    RunManifest::new(&cfg.name, &method.name, ratio, cfg.n_layers, fingerprint);
                manifest.save(&manifest_path)?;
                run.manifest = Some(manifest);
            }
        }

        if run.next == 0 {
            // step 1: X <- X' <- embedding of calibration data
            run.xs = embed_batches(cfg, params, calib);
            if method.needs_shift() {
                run.xs_shift = run.xs.clone();
            }
        }
        Ok(run)
    }

    /// Validate a loaded manifest against this session's inputs, verify
    /// the durable shards, and restore the activation streams for the
    /// first unwritten block.
    fn open_existing(
        &mut self,
        dir: &Path,
        manifest: RunManifest,
        fingerprint: u64,
        ratio: f64,
    ) -> Result<()> {
        let cfg = self.cfg;
        ensure!(
            manifest.config == cfg.name
                && manifest.method == self.method.name
                && manifest.ratio == ratio,
            "run directory {} belongs to config '{}' / method '{}' / ratio {} \
             but this session is config '{}' / method '{}' / ratio {} — use a \
             fresh run directory",
            dir.display(),
            manifest.config,
            manifest.method,
            manifest.ratio,
            cfg.name,
            self.method.name,
            ratio,
        );
        ensure!(
            manifest.fingerprint == fingerprint,
            "run fingerprint mismatch in {}: manifest records {} but these \
             inputs hash to {} — the config, method knobs, calibration data \
             or weights changed since the run started, so resuming would not \
             reproduce the uninterrupted artifact; remove the run directory \
             to start over",
            dir.display(),
            to_hex(manifest.fingerprint),
            to_hex(fingerprint),
        );
        ensure!(
            manifest.blocks.len() == cfg.n_layers,
            "run manifest in {} has {} block entries for a {}-layer config",
            dir.display(),
            manifest.blocks.len(),
            cfg.n_layers,
        );

        let resume_at = manifest.first_unwritten().unwrap_or(cfg.n_layers);

        // trust no durable file without its hash checking out
        for (i, entry) in manifest.blocks.iter().take(resume_at).enumerate() {
            let (Some(shard), Some(want)) = (&entry.shard, entry.shard_hash) else {
                bail!(
                    "block {i} is marked written but the manifest records no \
                     shard for it — remove the run directory to start over"
                );
            };
            let path = dir.join(shard);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading shard {} for resume", path.display()))?;
            let got = fnv1a64(&bytes);
            ensure!(
                got == want,
                "shard {} content hash {} does not match the manifest's {} — \
                 the file changed since it was written; remove the run \
                 directory to start over",
                path.display(),
                to_hex(got),
                to_hex(want),
            );
            if self.keep_blocks {
                self.kept.push(
                    decode_shard(cfg, &bytes)
                        .with_context(|| format!("decoding shard {}", path.display()))?,
                );
            }
        }

        if manifest.complete {
            ensure!(
                resume_at >= cfg.n_layers,
                "run manifest in {} is marked complete but block {} has no \
                 durable shard — remove the run directory to start over",
                dir.display(),
                resume_at,
            );
            self.skipped = cfg.n_layers;
        } else {
            self.resumed = resume_at;
            if resume_at > 0 && resume_at < cfg.n_layers {
                let entry = &manifest.blocks[resume_at - 1];
                let (Some(state), Some(want)) = (&entry.state, entry.state_hash) else {
                    bail!(
                        "block {} left no activation-stream snapshot to resume \
                         from — remove the run directory to start over",
                        resume_at - 1
                    );
                };
                let path = dir.join(state);
                let bytes = std::fs::read(&path).with_context(|| {
                    format!("reading stream snapshot {} for resume", path.display())
                })?;
                let got = fnv1a64(&bytes);
                ensure!(
                    got == want,
                    "stream snapshot {} content hash {} does not match the \
                     manifest's {} — remove the run directory to start over",
                    path.display(),
                    to_hex(got),
                    to_hex(want),
                );
                let (xs, xs_shift) = decode_state(&bytes, self.method.needs_shift())
                    .with_context(|| format!("decoding snapshot {}", path.display()))?;
                ensure!(
                    xs.len() == self.n_batches,
                    "stream snapshot holds {} batches but the calibration set \
                     has {} — the calibration data changed; remove the run \
                     directory to start over",
                    xs.len(),
                    self.n_batches,
                );
                self.xs = xs;
                self.xs_shift = xs_shift;
            }
        }
        self.next = resume_at;
        self.manifest = Some(manifest);
        Ok(())
    }

    pub fn total_blocks(&self) -> usize {
        self.cfg.n_layers
    }

    /// Blocks restored from a prior session's checkpoints.
    pub fn resumed_blocks(&self) -> usize {
        self.resumed
    }

    /// Blocks skipped because the run was already complete on open.
    pub fn skipped_blocks(&self) -> usize {
        self.skipped
    }

    /// Blocks solved by this session so far.
    pub fn solved_blocks(&self) -> usize {
        self.solved
    }

    /// Solve, persist (when checkpointed) and drop the next block.
    /// Returns `None` once every block is done. The loop body is the
    /// exact operation sequence of the historical `compress_model` —
    /// dense taps, per-group shifted taps / covariances / concurrent
    /// solves, refinement, stream advance — so outputs are bitwise
    /// unchanged.
    pub fn next_block(&mut self) -> Result<Option<BlockOutcome>> {
        let cfg = self.cfg;
        if self.next >= cfg.n_layers {
            return Ok(None);
        }
        let i = self.next;
        let t_block = Instant::now();
        let (params, method) = (self.params, self.method);
        let pool = self.pool;

        // dense taps on original inputs (X_j for every group, plus Y target)
        let t0 = Instant::now();
        let dense_taps = self.collector.dense_taps(cfg, params, i, &self.xs, &pool)?;
        self.report.secs_collect += t0.elapsed().as_secs_f64();

        // initialize L'_i <- L_i (exact full-rank factorization)
        let mut bf = exact_factors(cfg, params, i);

        for (tap_idx, linears) in GROUPS {
            // collect shifted tap from the *current* partial state of L'_i
            let t0 = Instant::now();
            let shift_tap: Option<Vec<Vec<f32>>> = if method.objective().needs_shift() {
                Some(
                    self.collector
                        .lr_tap(cfg, &bf, &self.xs_shift, tap_idx - 1, &pool)?,
                )
            } else {
                None
            };
            self.report.secs_collect += t0.elapsed().as_secs_f64();

            // accumulate covariances (shared by all linears in the group);
            // per-batch partials merge in batch order — thread-count
            // invariant by construction
            let t0 = Instant::now();
            let dim = if tap_idx == 4 { cfg.d_ff } else { cfg.d_model };
            let cov = match &shift_tap {
                Some(shift) => {
                    let pairs: Vec<(&[f32], &[f32])> = dense_taps.per_tap[tap_idx - 1]
                        .iter()
                        .zip(shift)
                        .map(|(o, s)| (o.as_slice(), s.as_slice()))
                        .collect();
                    CovTriple::accumulate(&pool, dim, &pairs)
                }
                None => {
                    let chunks: Vec<&[f32]> = dense_taps.per_tap[tap_idx - 1]
                        .iter()
                        .map(|o| o.as_slice())
                        .collect();
                    let mut cov = CovTriple::accumulate_same(&pool, dim, &chunks);
                    cov.mirror_same();
                    cov
                }
            };

            // the group's linears share `cov` and are independent given it
            // (paper §B.1): solve them concurrently. The paper's
            // block-sequential error propagation is intact because the
            // shifted tap above was collected before any factor changed.
            // Each solve gets an even share of the budget, passed down
            // explicitly to its linalg kernels (and installed, so any
            // auto-resolved stragglers inherit it too).
            let inner =
                Pool::exact((pool.threads() / linears.len().min(pool.threads())).max(1));
            let cov_ref = &cov;
            let alloc_ref = &self.allocation;
            let solved = pool.run(
                linears
                    .iter()
                    .map(|&lin| {
                        move || {
                            inner.install(|| {
                                let k = alloc_ref.rank_of(lin);
                                (lin, solve_one(method, cfg, params, i, lin, cov_ref, k, &inner))
                            })
                        }
                    })
                    .collect(),
            );
            // unwrap the per-linear Results in submission order so the
            // quant_errs push order (and any error surfaced) is
            // thread-count invariant
            for (lin, solved) in solved {
                let (f, qerr) = solved?;
                f.write_into(cfg, lin, &mut bf);
                if method.quantized() {
                    self.quant_errs.push(qerr);
                }
            }
            self.report.secs_solve += t0.elapsed().as_secs_f64();
        }

        // step 9: block-level local refinement
        if let Some(ropts) = method.refine_options() {
            let Some(engine) = self.collector.engine() else {
                bail!(
                    "method '{}' needs block refinement, which drives the AOT \
                     refine_step artifact — use an Engine-backed collector",
                    method.name
                );
            };
            let t0 = Instant::now();
            let x_shift_flat = concat_batches(&self.xs_shift);
            let y_flat = concat_batches(&dense_taps.y);
            let rep = refine_block(engine, cfg, &mut bf, &x_shift_flat, &y_flat, ropts, &pool)?;
            self.report.refine.push(rep);
            self.report.secs_refine += t0.elapsed().as_secs_f64();
        }

        // step 10: advance both streams
        if method.needs_shift() {
            let t0 = Instant::now();
            let advanced = self
                .collector
                .lr_forward_all(cfg, &bf, &self.xs_shift, &pool)?;
            self.xs_shift = advanced;
            self.report.secs_collect += t0.elapsed().as_secs_f64();
        }
        self.xs = dense_taps.y;

        // make the block durable, then drop it (unless kept)
        let shard = self.commit(i, &bf)?;
        if self.keep_blocks {
            self.kept.push(bf);
        }
        self.solved += 1;
        self.next = i + 1;
        Ok(Some(BlockOutcome {
            index: i,
            total: cfg.n_layers,
            secs: t_block.elapsed().as_secs_f64(),
            shard,
        }))
    }

    /// Persist block `i` per the module-level checkpoint protocol.
    /// Must run *after* the streams advance: `state_<i+1>.aat` is the
    /// streams entering block `i+1`.
    fn commit(&mut self, i: usize, bf: &BlockFactors) -> Result<Option<PathBuf>> {
        let Some(dir) = self.dir.clone() else {
            return Ok(None);
        };
        let manifest_path = dir.join("run.json");
        let Some(manifest) = self.manifest.as_mut() else {
            bail!("checkpointed run lost its manifest (internal invariant)");
        };

        // transient marker: factors exist in memory, shard not durable yet
        // (resume treats `solved` as unwritten and re-solves the block)
        manifest.blocks[i] = BlockEntry::solved();
        manifest.save(&manifest_path)?;

        // 1. durable factor shard
        let shard_name = format!("block_{i}.aat");
        let shard_path = dir.join(&shard_name);
        let shard_hash = write_shard(&shard_path, bf)
            .with_context(|| format!("writing shard {}", shard_path.display()))?;

        // 2. stream snapshot the next block resumes from
        let (state_name, state_hash) = if i + 1 < self.cfg.n_layers {
            let name = format!("state_{}.aat", i + 1);
            let path = dir.join(&name);
            let hash = write_state(&path, &self.xs, &self.xs_shift)
                .with_context(|| format!("writing stream snapshot {}", path.display()))?;
            (Some(name), Some(hash))
        } else {
            (None, None)
        };

        // 3. the shard and snapshot are durable — record them
        manifest.blocks[i] = BlockEntry::written(shard_name, shard_hash, state_name, state_hash);
        manifest.save(&manifest_path)?;

        // 4. the snapshot this block resumed from is obsolete now
        if i > 0 {
            let stale = dir.join(format!("state_{i}.aat"));
            if stale.exists() {
                std::fs::remove_file(&stale)
                    .with_context(|| format!("removing stale snapshot {}", stale.display()))?;
            }
        }
        Ok(Some(shard_path))
    }

    /// Complete the run: fold diagnostics, assemble the whole-model
    /// artifact (streamed shard by shard — never all blocks in memory),
    /// and mark the manifest complete.
    fn finalize(&mut self) -> Result<()> {
        ensure!(
            self.next >= self.cfg.n_layers,
            "compress run is incomplete ({} of {} blocks done) — drive \
             next_block() to completion; the checkpoints persist, so a later \
             session can resume",
            self.next,
            self.cfg.n_layers,
        );
        self.report.quant_err = if self.quant_errs.is_empty() {
            0.0
        } else {
            // aasvd-lint: allow(float-reduce): sequential mean over per-block diagnostics in fixed block order; report-only
            self.quant_errs.iter().sum::<f64>() / self.quant_errs.len() as f64
        };

        let Some(artifact) = self.artifact.clone() else {
            return Ok(());
        };

        // a prior session may have finalized already: keep the artifact
        // if it still verifies, rebuild it bit-identically otherwise
        if let Some(manifest) = self.manifest.as_ref() {
            if manifest.complete {
                if let (Some(want), Ok(bytes)) =
                    (manifest.artifact_hash, std::fs::read(&artifact))
                {
                    if fnv1a64(&bytes) == want {
                        self.artifact_hash = Some(want);
                        if let Some(dir) = self.dir.as_ref() {
                            sweep_states(dir, self.cfg.n_layers);
                        }
                        return Ok(());
                    }
                }
            }
        }

        let hash = if self.method.quantized() {
            // Quantized methods persist what serving actually loads: the
            // int8 factors plus their scale tables (AAT2), not a 4x-larger
            // f32 dequantization of them. The per-block QuantBlockFactors
            // are ~1/4 the f32 working set, so holding the archive in
            // memory here keeps the peak bound of the streaming loop.
            let mut qblocks = Vec::with_capacity(self.cfg.n_layers);
            for i in 0..self.cfg.n_layers {
                let qb = if i < self.kept.len() {
                    QuantBlockFactors::from_block(self.cfg, &self.kept[i])
                } else {
                    let Some(dir) = self.dir.as_ref() else {
                        bail!(
                            "block {i} is neither in memory nor on disk \
                             (internal invariant)"
                        );
                    };
                    let bf = load_shard(self.cfg, &dir.join(format!("block_{i}.aat")))?;
                    QuantBlockFactors::from_block(self.cfg, &bf)
                };
                match qb {
                    Ok(qb) => qblocks.push(qb),
                    Err(e) => bail!("quantizing block {i} for the artifact: {e}"),
                }
            }
            save_quant_blocks(&qblocks, &artifact)
                .with_context(|| format!("assembling artifact {}", artifact.display()))?;
            let bytes = std::fs::read(&artifact)
                .with_context(|| format!("hashing artifact {}", artifact.display()))?;
            fnv1a64(&bytes)
        } else {
            let mut w = ArchiveWriter::create(&artifact, 2 * self.cfg.n_layers)
                .with_context(|| format!("assembling artifact {}", artifact.display()))?;
            for i in 0..self.cfg.n_layers {
                let (fdata, mdata) = if i < self.kept.len() {
                    (
                        self.kept[i].factors.data.clone(),
                        self.kept[i].masks.data.clone(),
                    )
                } else {
                    let Some(dir) = self.dir.as_ref() else {
                        bail!(
                            "block {i} is neither in memory nor on disk \
                             (internal invariant)"
                        );
                    };
                    let bf = load_shard(self.cfg, &dir.join(format!("block_{i}.aat")))?;
                    (bf.factors.data, bf.masks.data)
                };
                w.append(
                    &format!("blocks.{i}.factors"),
                    &Tensor::new(vec![fdata.len()], fdata),
                )?;
                w.append(
                    &format!("blocks.{i}.masks"),
                    &Tensor::new(vec![mdata.len()], mdata),
                )?;
            }
            w.finish()
                .with_context(|| format!("assembling artifact {}", artifact.display()))?
        };
        self.artifact_hash = Some(hash);

        if let Some(dir) = self.dir.clone() {
            let Some(manifest) = self.manifest.as_mut() else {
                bail!("checkpointed run lost its manifest (internal invariant)");
            };
            manifest.complete = true;
            manifest.artifact_hash = Some(hash);
            manifest.save(dir.join("run.json"))?;
            sweep_states(&dir, self.cfg.n_layers);
        }
        Ok(())
    }

    /// Finish a (typically checkpointed) run: write the artifact and
    /// return the accounting summary.
    pub fn finish(mut self) -> Result<CompressSummary> {
        self.finalize()?;
        Ok(CompressSummary {
            total: self.cfg.n_layers,
            solved: self.solved,
            resumed: self.resumed,
            skipped: self.skipped,
            report: self.report,
            allocation: self.allocation,
            artifact: self.artifact,
            artifact_hash: self.artifact_hash,
        })
    }

    /// Finish an in-memory (`keep_blocks`) run as a [`CompressedModel`].
    pub fn into_model(mut self) -> Result<CompressedModel> {
        self.finalize()?;
        ensure!(
            self.kept.len() == self.cfg.n_layers,
            "into_model needs the keep_blocks option; this run streamed its \
             blocks to disk — load the artifact with load_blocks instead"
        );
        Ok(CompressedModel {
            blocks: self.kept,
            allocation: self.allocation,
            report: self.report,
        })
    }
}

/// FNV-1a 64 over every input that determines the output bits: config
/// dims, method knobs, rank allocation, calibration tokens, and the
/// dense weights. The thread count is deliberately excluded — artifacts
/// are bitwise thread-count invariant, so a run may resume under a
/// different worker count.
fn run_fingerprint(
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
    method: &Method,
    ratio: f64,
    allocation: &Allocation,
) -> u64 {
    let mut h = Fnv64::new();
    h.update(cfg.name.as_bytes());
    for dim in [
        cfg.vocab,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_layers,
        cfg.d_ff,
        cfg.batch,
        cfg.seq,
        cfg.refine_batch,
        cfg.train_batch,
    ] {
        h.update_u64(dim as u64);
    }
    h.update_u64(cfg.rope_theta.to_bits());
    h.update(method.name.as_bytes());
    h.update(method.objective().name().as_bytes());
    h.update(&[u8::from(method.asvd_diag()), u8::from(method.quantized())]);
    h.update(method.scheme().name().as_bytes());
    match method.refine_options() {
        None => h.update(&[0]),
        Some(r) => {
            h.update(&[1]);
            h.update_u64(r.epochs as u64);
            h.update_u64(r.base_lr.to_bits());
            h.update_u64(r.warmup_frac.to_bits());
            h.update_u64(r.plateau_tol.to_bits());
            h.update_u64(r.seed);
        }
    }
    h.update_u64(ratio.to_bits());
    for &k in &allocation.ranks {
        h.update_u64(k as u64);
    }
    h.update_u64(calib.len() as u64);
    for b in calib {
        h.update_i32s(&b.tokens);
        h.update_u64(b.real_rows as u64);
    }
    h.update_f32s(&params.data);
    h.finish()
}

/// One block's factors as a durable `.aat` shard; returns the file hash.
fn write_shard(path: &Path, bf: &BlockFactors) -> Result<u64> {
    let mut w = ArchiveWriter::create(path, 2)?;
    w.append(
        "factors",
        &Tensor::new(vec![bf.factors.data.len()], bf.factors.data.clone()),
    )?;
    w.append(
        "masks",
        &Tensor::new(vec![bf.masks.data.len()], bf.masks.data.clone()),
    )?;
    w.finish()
}

fn decode_shard(cfg: &Config, bytes: &[u8]) -> Result<BlockFactors> {
    let arch = TensorArchive::from_bytes(bytes)?;
    let mut bf = BlockFactors::zeros(cfg);
    let f = arch.get("factors").context("shard is missing 'factors'")?;
    let m = arch.get("masks").context("shard is missing 'masks'")?;
    ensure!(
        f.data.len() == bf.factors.data.len() && m.data.len() == bf.masks.data.len(),
        "shard tensor sizes do not match this config's factor layout"
    );
    bf.factors.data.copy_from_slice(&f.data);
    bf.masks.data.copy_from_slice(&m.data);
    Ok(bf)
}

fn load_shard(cfg: &Config, path: &Path) -> Result<BlockFactors> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading shard {}", path.display()))?;
    decode_shard(cfg, &bytes).with_context(|| format!("decoding shard {}", path.display()))
}

/// Snapshot the activation streams entering the next block; returns the
/// file hash. The f32 bits round-trip exactly, so a restored stream is
/// indistinguishable from one that never left memory.
fn write_state(path: &Path, xs: &[Vec<f32>], xs_shift: &[Vec<f32>]) -> Result<u64> {
    let mut w = ArchiveWriter::create(path, xs.len() + xs_shift.len())?;
    for (b, x) in xs.iter().enumerate() {
        w.append(&format!("xs.{b}"), &Tensor::new(vec![x.len()], x.clone()))?;
    }
    for (b, x) in xs_shift.iter().enumerate() {
        w.append(
            &format!("xs_shift.{b}"),
            &Tensor::new(vec![x.len()], x.clone()),
        )?;
    }
    w.finish()
}

fn decode_state(bytes: &[u8], needs_shift: bool) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
    let arch = TensorArchive::from_bytes(bytes)?;
    let collect = |prefix: &str| -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = Vec::new();
        while let Some(t) = arch.get(&format!("{prefix}.{}", out.len())) {
            out.push(t.data.clone());
        }
        out
    };
    let xs = collect("xs");
    ensure!(!xs.is_empty(), "stream snapshot holds no activation batches");
    let xs_shift = collect("xs_shift");
    if needs_shift {
        ensure!(
            xs_shift.len() == xs.len(),
            "stream snapshot is missing the shifted stream this method needs"
        );
    }
    Ok((xs, xs_shift))
}

/// Remove stream snapshots once the artifact is durable: they are pure
/// resume state and only waste space afterwards. Best-effort — a
/// leftover snapshot is harmless (complete runs never read it).
fn sweep_states(dir: &Path, n_layers: usize) {
    for b in 1..n_layers {
        let p = dir.join(format!("state_{b}.aat"));
        if p.exists() {
            let _ = std::fs::remove_file(&p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Batcher, Corpus, Domain};
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn setup() -> (Config, FlatStore, Vec<TokenBatch>) {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(9));
        let corpus = Corpus::generate(Domain::Wiki, 10_000, 7);
        let calib: Vec<_> = Batcher::new(cfg.batch, cfg.seq)
            .sequential(&corpus.train, 2)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();
        assert!(!calib.is_empty());
        (cfg, params, calib)
    }

    #[test]
    fn fingerprint_tracks_inputs_but_not_threads() {
        let (cfg, params, calib) = setup();
        let m1 = Method::builder("anchored")
            .objective(crate::compress::Objective::Anchored)
            .threads(1)
            .build();
        let m4 = Method::builder("anchored")
            .objective(crate::compress::Objective::Anchored)
            .threads(4)
            .build();
        let alloc = Allocation::uniform(&cfg, 0.6, m1.scheme());
        let base = run_fingerprint(&cfg, &params, &calib, &m1, 0.6, &alloc);

        // thread count must NOT move the fingerprint (cross-thread resume)
        assert_eq!(
            base,
            run_fingerprint(&cfg, &params, &calib, &m4, 0.6, &alloc)
        );
        // ratio does
        let alloc2 = Allocation::uniform(&cfg, 0.5, m1.scheme());
        assert_ne!(
            base,
            run_fingerprint(&cfg, &params, &calib, &m1, 0.5, &alloc2)
        );
        // weights do
        let mut p2 = params.clone();
        p2.data[0] += 1.0;
        assert_ne!(base, run_fingerprint(&cfg, &p2, &calib, &m1, 0.6, &alloc));
        // calibration data does
        let fewer = &calib[..calib.len() - 1];
        assert_ne!(base, run_fingerprint(&cfg, &params, fewer, &m1, 0.6, &alloc));
        // method identity does
        let other = Method::builder("other")
            .objective(crate::compress::Objective::Anchored)
            .build();
        assert_ne!(
            base,
            run_fingerprint(&cfg, &params, &calib, &other, 0.6, &alloc)
        );
    }

    #[test]
    fn state_snapshot_roundtrips_bit_exactly() {
        let dir = std::env::temp_dir().join("aasvd-run-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state_rt.aat");
        let xs = vec![vec![1.0f32, -0.0, 3.5e-20], vec![f32::MIN_POSITIVE; 4]];
        let xs_shift = vec![vec![2.0f32; 3], vec![0.25f32; 4]];
        let hash = write_state(&path, &xs, &xs_shift).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(hash, fnv1a64(&bytes));
        let (rxs, rshift) = decode_state(&bytes, true).unwrap();
        // bit-for-bit: -0.0 stays -0.0, subnormals survive
        for (a, b) in xs.iter().zip(&rxs) {
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        for (a, b) in xs_shift.iter().zip(&rshift) {
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        // a method without the shifted stream accepts its absence
        let path2 = dir.join("state_noshift.aat");
        write_state(&path2, &xs, &[]).unwrap();
        let (_, empty) = decode_state(&std::fs::read(&path2).unwrap(), false).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn shard_roundtrips_through_bytes() {
        let (cfg, params, _) = setup();
        let bf = exact_factors(&cfg, &params, 0);
        let dir = std::env::temp_dir().join("aasvd-run-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard_rt.aat");
        let hash = write_shard(&path, &bf).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(hash, fnv1a64(&bytes));
        let back = decode_shard(&cfg, &bytes).unwrap();
        assert_eq!(back.factors.data, bf.factors.data);
        assert_eq!(back.masks.data, bf.masks.data);
    }
}
