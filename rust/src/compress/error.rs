//! Layer-wise error profiling (Figures 1 and 4).
//!
//! Runs the dense and compressed models side by side on held-out data and
//! records, per block: MSE and cosine distance of the attention output
//! projection (O-proj), the MLP down projection, and the full block output
//! — the three series of Figure 4. The dense stream propagates dense
//! activations; the compressed stream propagates compressed activations, so
//! profiles include accumulated upstream error exactly as in the paper.

use super::pipeline::pack_block_params;
use crate::data::TokenBatch;
use crate::model::forward::linear;
use crate::model::lowrank::BlockFactors;
use crate::model::{Config, FlatStore};
use crate::runtime::{Engine, Value};
use crate::util::stats::{cosine_distance, mse};
use anyhow::Result;

#[derive(Clone, Debug, Default)]
pub struct LayerErrors {
    pub o_proj_mse: Vec<f64>,
    pub o_proj_cos: Vec<f64>,
    pub down_mse: Vec<f64>,
    pub down_cos: Vec<f64>,
    pub block_mse: Vec<f64>,
    pub block_cos: Vec<f64>,
}

/// Profile errors across depth on `eval` batches (uses the first batch set
/// only — profiles are qualitative curves, not precision statistics).
pub fn depth_profile(
    engine: &Engine,
    cfg: &Config,
    params: &FlatStore,
    blocks: &[BlockFactors],
    eval: &[TokenBatch],
) -> Result<LayerErrors> {
    let mut errs = LayerErrors::default();
    let mut xs_dense = super::pipeline::embed_batches(cfg, params, eval);
    let mut xs_comp = xs_dense.clone();
    let (d, f) = (cfg.d_model, cfg.d_ff);

    for (i, bf) in blocks.iter().enumerate() {
        let bp = pack_block_params(cfg, params, i);
        let mut o_mse = 0.0;
        let mut o_cos = 0.0;
        let mut d_mse = 0.0;
        let mut d_cos = 0.0;
        let mut b_mse = 0.0;
        let mut b_cos = 0.0;

        for (xd, xc) in xs_dense.iter_mut().zip(xs_comp.iter_mut()) {
            let dense = engine.run(
                &cfg.name,
                "block_collect",
                &[Value::F32(&bp), Value::F32(xd)],
            )?;
            let comp = engine.run(
                &cfg.name,
                "block_lr_collect",
                &[
                    Value::F32(&bf.factors.data),
                    Value::F32(&bf.masks.data),
                    Value::F32(xc),
                ],
            )?;
            let rows = xd.len() / d;
            // O-proj outputs: wo(o_in) vs wo'(o_in')
            let mut dense_o = vec![0f32; rows * d];
            linear(
                &dense[2].f32,
                params.view(&format!("blocks.{i}.wo")),
                d,
                d,
                &mut dense_o,
            );
            let mut comp_o = vec![0f32; rows * d];
            bf.apply_linear(cfg, "wo", &comp[2].f32, &mut comp_o);
            o_mse += mse(&comp_o, &dense_o);
            o_cos += cosine_distance(&comp_o, &dense_o);
            // down-proj outputs
            let mut dense_d = vec![0f32; rows * d];
            linear(
                &dense[4].f32,
                params.view(&format!("blocks.{i}.w_down")),
                f,
                d,
                &mut dense_d,
            );
            let mut comp_d = vec![0f32; rows * d];
            bf.apply_linear(cfg, "w_down", &comp[4].f32, &mut comp_d);
            d_mse += mse(&comp_d, &dense_d);
            d_cos += cosine_distance(&comp_d, &dense_d);
            // block outputs
            b_mse += mse(&comp[0].f32, &dense[0].f32);
            b_cos += cosine_distance(&comp[0].f32, &dense[0].f32);
            // advance both streams
            *xd = dense[0].f32.clone();
            *xc = comp[0].f32.clone();
        }
        let nb = xs_dense.len() as f64;
        errs.o_proj_mse.push(o_mse / nb);
        errs.o_proj_cos.push(o_cos / nb);
        errs.down_mse.push(d_mse / nb);
        errs.down_cos.push(d_cos / nb);
        errs.block_mse.push(b_mse / nb);
        errs.block_cos.push(b_cos / nb);
    }
    Ok(errs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::lowrank::exact_factors;
    use crate::util::rng::Rng;

    /// With exact full-rank factors the profile must be ~zero everywhere;
    /// with truncated factors it must be larger and grow with truncation.
    #[test]
    fn profile_zero_for_exact_and_grows_with_truncation() {
        let Ok(engine) = Engine::new("artifacts") else { return };
        if engine.entry("tiny").is_err() {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(5));
        let corpus = crate::data::Corpus::generate(crate::data::Domain::Wiki, 20_000, 9);
        let batcher = crate::data::Batcher::new(cfg.batch, cfg.seq);
        let eval: Vec<_> = batcher
            .sequential(&corpus.test, 2)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();

        let exact: Vec<_> = (0..cfg.n_layers)
            .map(|i| exact_factors(&cfg, &params, i))
            .collect();
        let p0 = depth_profile(&engine, &cfg, &params, &exact, &eval).unwrap();
        assert!(p0.block_mse.iter().all(|&e| e < 1e-6), "{:?}", p0.block_mse);

        let mut trunc = exact.clone();
        for bf in trunc.iter_mut() {
            for lin in crate::model::BLOCK_LINEARS {
                bf.set_rank(lin, cfg.kmax(lin) / 4);
            }
        }
        let p1 = depth_profile(&engine, &cfg, &params, &trunc, &eval).unwrap();
        assert!(p1.block_mse[0] > p0.block_mse[0] * 100.0);
        assert!(p1.o_proj_cos.iter().all(|&c| (0.0..=2.0).contains(&c)));
        // error accumulates: last block >= first block (weak monotonicity)
        assert!(
            p1.block_mse[cfg.n_layers - 1] >= p1.block_mse[0] * 0.5,
            "{:?}",
            p1.block_mse
        );
    }
}
