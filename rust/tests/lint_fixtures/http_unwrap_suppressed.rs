// aasvd-lint: path=src/serve/http/fixture.rs

pub fn first_header(headers: &[(String, String)]) -> &str {
    // aasvd-lint: allow(serve-unwrap): fixture justification — caller guarantees a non-empty header set
    headers.first().unwrap().1.as_str()
}
