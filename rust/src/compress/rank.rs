//! Rank allocation and parameter-budget accounting (paper §B.3, §B.4).
//!
//! Standard scheme: a layer at ratio ρ stores k(m+n) of mn parameters,
//!   k = ρ·mn/(m+n)          (restricts k ≤ mn/(m+n), i.e. ρ ≤ 1).
//! Dobi-style remapping stores max(m,n)·k full-precision-equivalent units
//! (smaller factor + top rows of the larger factor in 8-bit), so
//!   k = ρ·min(m,n)          spanning the full rank range.

use super::quant::QUANT_GROUP_ROWS;
use crate::model::config::{Config, BLOCK_LINEARS};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankScheme {
    Standard,
    Remap,
}

impl RankScheme {
    pub fn name(&self) -> &'static str {
        match self {
            RankScheme::Standard => "standard",
            RankScheme::Remap => "remap",
        }
    }

    /// Truncation rank for one linear at parameter ratio `rho`.
    pub fn rank(&self, m: usize, n: usize, rho: f64) -> usize {
        let k = match self {
            RankScheme::Standard => rho * (m * n) as f64 / (m + n) as f64,
            RankScheme::Remap => rho * m.min(n) as f64,
        };
        (k.round() as usize).clamp(1, m.min(n))
    }

    /// Stored parameter count (full-precision-equivalent units) of one
    /// linear at rank k.
    pub fn stored(&self, m: usize, n: usize, k: usize) -> f64 {
        match self {
            RankScheme::Standard => (k * (m + n)) as f64,
            // B.4: 0.5·2·min·k (8-bit halves) + (max−min)·k full precision
            RankScheme::Remap => (m.max(n) * k) as f64,
        }
    }
}

/// Per-linear rank allocation for a whole model at a uniform ratio
/// (the paper's default; §5 discusses non-uniform allocation as future work).
#[derive(Clone, Debug)]
pub struct Allocation {
    pub scheme: RankScheme,
    pub ratio: f64,
    /// rank per block linear, in BLOCK_LINEARS order (same for all blocks
    /// under uniform allocation)
    pub ranks: Vec<usize>,
}

impl Allocation {
    pub fn uniform(cfg: &Config, ratio: f64, scheme: RankScheme) -> Allocation {
        let ranks = BLOCK_LINEARS
            .iter()
            .map(|lin| {
                let (m, n) = cfg.linear_dims(lin);
                scheme.rank(m, n, ratio)
            })
            .collect();
        Allocation {
            scheme,
            ratio,
            ranks,
        }
    }

    pub fn rank_of(&self, lin: &str) -> usize {
        let idx = BLOCK_LINEARS.iter().position(|l| *l == lin).unwrap();
        self.ranks[idx]
    }

    /// Achieved compression ratio over block-linear parameters.
    pub fn achieved_ratio(&self, cfg: &Config) -> f64 {
        let mut stored = 0.0;
        let mut dense = 0.0;
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            stored += self.scheme.stored(m, n, self.rank_of(lin));
            dense += (m * n) as f64;
        }
        stored / dense
    }

    /// Achieved compression ratio when the factors are *actually stored*
    /// as int8 with per-group f32 scales — what a quantized method's
    /// artifact and serving backend hold. The scheme's `stored` is the
    /// paper's full-precision-equivalent approximation; this is the real
    /// byte accounting, in dense-f32-weight units.
    pub fn achieved_ratio_quantized(&self, cfg: &Config) -> f64 {
        let mut stored = 0.0;
        let mut dense = 0.0;
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            stored += quant_stored(m, n, self.rank_of(lin));
            dense += (m * n) as f64;
        }
        stored / dense
    }

    /// Total model parameters (full-precision-equivalent) including the
    /// uncompressed embed/head/norm tensors.
    pub fn total_params(&self, cfg: &Config) -> f64 {
        let fixed = (2 * cfg.vocab * cfg.d_model
            + cfg.d_model
            + cfg.n_layers * 2 * cfg.d_model) as f64;
        let mut blocks = 0.0;
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            blocks += self.scheme.stored(m, n, self.rank_of(lin));
        }
        fixed + cfg.n_layers as f64 * blocks
    }
}

/// Stored size of one linear's int8 factor pair at rank k, in
/// f32-weight units: each int8 entry counts 1/4 and each per-group
/// per-column f32 scale counts 1 (group size [`QUANT_GROUP_ROWS`],
/// capped at the factor's row count — mirrors `QuantMatrix::quantize`).
/// Multiplied by 4 this is exactly `QuantMatrix::bytes` of the pair.
pub fn quant_stored(m: usize, n: usize, k: usize) -> f64 {
    let groups = |rows: usize| rows.div_ceil(rows.min(QUANT_GROUP_ROWS).max(1));
    0.25 * (k * (m + n)) as f64 + (k * (groups(m) + groups(n))) as f64
}

/// Dense model parameter count.
pub fn dense_params(cfg: &Config) -> f64 {
    (2 * cfg.vocab * cfg.d_model
        + cfg.d_model
        + cfg.n_layers * (2 * cfg.d_model + cfg.block_linear_params())) as f64
}

/// Memory-budget row (Table 4): find the largest uniform ratio whose total
/// parameter bytes fit `budget_frac` of the dense model.
pub fn ratio_for_budget(cfg: &Config, budget_frac: f64, scheme: RankScheme) -> f64 {
    let dense = dense_params(cfg);
    let mut lo = 0.02;
    let mut hi = 1.0;
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        let total = Allocation::uniform(cfg, mid, scheme).total_params(cfg);
        if total <= budget_frac * dense {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_rank_formula() {
        // m = n = 100, rho = 0.5: k = 0.5*10000/200 = 25
        assert_eq!(RankScheme::Standard.rank(100, 100, 0.5), 25);
        // full ratio caps at mn/(m+n)
        assert_eq!(RankScheme::Standard.rank(100, 100, 1.0), 50);
    }

    #[test]
    fn remap_rank_formula() {
        assert_eq!(RankScheme::Remap.rank(100, 100, 0.5), 50);
        assert_eq!(RankScheme::Remap.rank(100, 300, 0.8), 80);
        // spans the full valid range (footnote 4)
        assert_eq!(RankScheme::Remap.rank(100, 100, 1.0), 100);
    }

    #[test]
    fn ranks_clamped_to_valid() {
        assert_eq!(RankScheme::Standard.rank(10, 10, 0.0001), 1);
        assert!(RankScheme::Standard.rank(10, 10, 5.0) <= 10);
    }

    #[test]
    fn achieved_ratio_tracks_request() {
        let cfg = Config::builtin("base").unwrap();
        for rho in [0.8, 0.6, 0.4] {
            for scheme in [RankScheme::Standard, RankScheme::Remap] {
                let a = Allocation::uniform(&cfg, rho, scheme);
                let got = a.achieved_ratio(&cfg);
                assert!(
                    (got - rho).abs() < 0.05,
                    "{scheme:?} rho={rho} achieved={got}"
                );
            }
        }
    }

    #[test]
    fn remap_allows_higher_rank_at_same_budget() {
        let cfg = Config::builtin("base").unwrap();
        let std_a = Allocation::uniform(&cfg, 0.8, RankScheme::Standard);
        let rem_a = Allocation::uniform(&cfg, 0.8, RankScheme::Remap);
        // same nominal ratio, remap keeps more singular directions on the
        // square attention projections
        assert!(rem_a.rank_of("wq") > std_a.rank_of("wq"));
    }

    #[test]
    fn budget_solver_hits_target() {
        let cfg = Config::builtin("base").unwrap();
        let dense = dense_params(&cfg);
        for frac in [0.9, 0.7, 0.5] {
            let rho = ratio_for_budget(&cfg, frac, RankScheme::Standard);
            let total = Allocation::uniform(&cfg, rho, RankScheme::Standard)
                .total_params(&cfg);
            assert!(total <= frac * dense * 1.001);
            // and not wastefully below target
            assert!(total >= frac * dense * 0.9, "frac {frac}: {total}");
        }
    }

    #[test]
    fn quant_stored_matches_quant_matrix_bytes() {
        use crate::compress::quant::QuantMatrix;
        let cfg = Config::builtin("base").unwrap();
        for lin in BLOCK_LINEARS {
            let (m, n) = cfg.linear_dims(lin);
            let k = RankScheme::Remap.rank(m, n, 0.6);
            let u = vec![0.5f32; m * k];
            let v = vec![0.25f32; n * k];
            let qu = QuantMatrix::quantize(&u, m, k).unwrap();
            let qv = QuantMatrix::quantize(&v, n, k).unwrap();
            // the accounting formula is the real byte count, not a model
            let units4 = quant_stored(m, n, k) * 4.0;
            assert_eq!(units4 as usize, qu.bytes() + qv.bytes(), "{lin}");
        }
    }

    #[test]
    fn quantized_ratio_reflects_int8_storage() {
        let cfg = Config::builtin("base").unwrap();
        let a = Allocation::uniform(&cfg, 0.6, RankScheme::Remap);
        let f32_ratio = a.achieved_ratio(&cfg);
        let q_ratio = a.achieved_ratio_quantized(&cfg);
        // int8 storage is strictly cheaper than the full-precision-
        // equivalent approximation the scheme reports
        assert!(
            q_ratio < f32_ratio,
            "quantized {q_ratio} should undercut f32-equivalent {f32_ratio}"
        );
        // ...but not free: scales keep it above a pure-int8 quarter of
        // the rank-k f32 ratio
        assert!(q_ratio > 0.0);
    }

    #[test]
    fn dense_params_sanity() {
        let cfg = Config::builtin("tiny").unwrap();
        let lay = crate::model::params::param_layout(&cfg);
        assert_eq!(dense_params(&cfg) as usize, lay.total);
    }
}
