//! Minimal fixed-size thread pool (the offline build has no tokio/rayon).
//!
//! Used by the serving layer for request handling and by benches for
//! load generation. Jobs are boxed closures over an mpsc channel; `join`
//! blocks until all submitted jobs have completed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    submitted: AtomicUsize,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                thread::Builder::new()
                    .name(format!("aasvd-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
            submitted: AtomicUsize::new(0),
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    pub fn jobs_submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_submitted(), 100);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                thread::sleep(std::time::Duration::from_millis(20));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        pool.join();
        drop(pool); // must not hang
    }
}
