//! Fixture-driven tests for the `aasvd-lint` determinism pass: every
//! rule fires on its known-bad fixture, every suppression silences it,
//! the JSON report parses, scanning is deterministic — and the repo's
//! own tree is clean (the invariant CI's `lint` job enforces).

use std::path::{Path, PathBuf};

use aasvd::lint::{render_json, scan_file, scan_tree, RULES};
use aasvd::util::json::Json;

fn manifest_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_dir() -> PathBuf {
    manifest_dir().join("tests").join("lint_fixtures")
}

fn rules_fired(file: &Path) -> Vec<String> {
    scan_file(file)
        .unwrap_or_else(|e| panic!("scan {}: {e}", file.display()))
        .into_iter()
        .map(|v| v.rule)
        .collect()
}

/// fixture file -> rule names expected to fire, in line order
/// (duplicates = multiple firing lines).
const EXPECTED: &[(&str, &[&str])] = &[
    ("adhoc_parallelism_fire.rs", &["adhoc-parallelism"]),
    ("adhoc_parallelism_suppressed.rs", &[]),
    ("hash_iter_fire.rs", &["hash-iter", "hash-iter", "hash-iter"]),
    ("hash_iter_suppressed.rs", &[]),
    ("float_reduce_fire.rs", &["float-reduce"]),
    ("float_reduce_suppressed.rs", &[]),
    ("float_reduce_sanctioned.rs", &[]),
    ("float_cmp_fire.rs", &["float-cmp"]),
    ("float_cmp_suppressed.rs", &[]),
    ("env_var_fire.rs", &["env-var"]),
    ("env_var_suppressed.rs", &[]),
    ("wallclock_fire.rs", &["wallclock"]),
    ("wallclock_suppressed.rs", &[]),
    ("serve_unwrap_fire.rs", &["serve-unwrap", "serve-unwrap"]),
    ("serve_unwrap_suppressed.rs", &[]),
    ("suppression_unjustified.rs", &["lint-directive", "wallclock"]),
    ("unknown_rule.rs", &["lint-directive"]),
    ("comments_ok.rs", &[]),
    ("test_mod_ok.rs", &[]),
    // src/serve/http/ policy: wallclock now applies there (latency
    // measurement must carry a justified suppression), and serve-unwrap
    // is inherited from src/serve/
    ("http_wallclock_fire.rs", &["wallclock"]),
    ("http_wallclock_suppressed.rs", &[]),
    ("http_unwrap_fire.rs", &["serve-unwrap"]),
    ("http_unwrap_suppressed.rs", &[]),
    // src/serve/kv_pool.rs policy: the prefix-cache trie inherits
    // serve-unwrap and float-cmp from its tree, and is the one serve/
    // file additionally covered by hash-iter — trie iteration order
    // decides LRU eviction ties, so a HashMap there would make 429s
    // under pressure nondeterministic
    (
        "kv_pool_hash_iter_fire.rs",
        &["hash-iter", "hash-iter", "hash-iter"],
    ),
    ("kv_pool_unwrap_fire.rs", &["serve-unwrap"]),
    ("kv_pool_float_cmp_fire.rs", &["float-cmp"]),
    ("kv_pool_suppressed.rs", &[]),
    // checkpoint-persistence policy: serve-unwrap extends to the files
    // that write/read the compress-run manifest and shards — a panic
    // mid-commit would defeat the crash-consistency protocol, so every
    // fallible path there must thread a Result
    ("manifest_unwrap_fire.rs", &["serve-unwrap"]),
    ("manifest_unwrap_suppressed.rs", &[]),
    ("compress_run_unwrap_fire.rs", &["serve-unwrap"]),
    ("compress_run_env_var_fire.rs", &["env-var"]),
    // src/model/quant_lowrank.rs policy: the fused int8 kernels join the
    // sanctioned banded-kernel files (ordered float reductions are the
    // bitwise fused-vs-dequant contract), and the artifact decode path
    // joins the unwrap-hardened persistence surface — a panic there
    // kills serving at artifact-load time
    ("quant_lowrank_float_reduce_sanctioned.rs", &[]),
    ("quant_lowrank_unwrap_fire.rs", &["serve-unwrap"]),
];

#[test]
fn fixtures_fire_and_suppress_as_pinned() {
    for (name, expected) in EXPECTED {
        let path = fixture_dir().join(name);
        assert!(path.is_file(), "missing fixture {name}");
        let fired = rules_fired(&path);
        assert_eq!(&fired, expected, "unexpected findings in fixture {name}");
    }
}

#[test]
fn every_rule_has_a_fire_and_a_suppress_fixture() {
    for rule in RULES {
        let stem = rule.name.replace('-', "_");
        let fire = format!("{stem}_fire.rs");
        let suppressed = format!("{stem}_suppressed.rs");
        let fire_row = EXPECTED
            .iter()
            .find(|(n, _)| *n == fire)
            .unwrap_or_else(|| panic!("no firing fixture for rule {}", rule.name));
        assert!(
            fire_row.1.contains(&rule.name),
            "fixture {fire} does not fire rule {}",
            rule.name
        );
        let suppress_row = EXPECTED
            .iter()
            .find(|(n, _)| *n == suppressed)
            .unwrap_or_else(|| panic!("no suppressed fixture for rule {}", rule.name));
        assert!(
            suppress_row.1.is_empty(),
            "fixture {suppressed} should be fully suppressed"
        );
    }
}

#[test]
fn corpus_fails_as_a_tree_and_covers_every_rule() {
    let (files, violations) = scan_tree(&fixture_dir()).expect("scan fixture corpus");
    assert!(files >= EXPECTED.len(), "walker missed fixture files");
    assert!(
        !violations.is_empty(),
        "the known-bad corpus must produce violations"
    );
    for rule in RULES {
        assert!(
            violations.iter().any(|v| v.rule == rule.name),
            "rule {} never fired across the corpus",
            rule.name
        );
    }
    assert!(
        violations.iter().any(|v| v.rule == "lint-directive"),
        "malformed directives must be reported"
    );
}

#[test]
fn repo_tree_is_clean() {
    // the invariant CI enforces: the shipped tree has zero violations
    // (fixed or suppressed-with-justification)
    for tree in ["src", "tests", "benches", "bin"] {
        let root = manifest_dir().join(tree);
        let (files, violations) = scan_tree(&root).expect("scan repo tree");
        assert!(files > 0, "no files under {tree}/");
        assert!(
            violations.is_empty(),
            "lint violations in {tree}/:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}

#[test]
fn scanning_is_deterministic() {
    let a = scan_tree(&fixture_dir()).expect("first scan");
    let b = scan_tree(&fixture_dir()).expect("second scan");
    assert_eq!(a.0, b.0);
    assert_eq!(a.1, b.1);
}

#[test]
fn json_report_parses_with_the_repo_parser() {
    let file = fixture_dir().join("serve_unwrap_fire.rs");
    let violations = scan_file(&file).expect("scan fixture");
    let report = render_json(&violations, 1);
    let parsed = Json::parse(&report.to_string_pretty()).expect("valid json");
    assert_eq!(parsed.req("files_scanned").as_usize(), Some(1));
    assert_eq!(parsed.req("clean").as_bool(), Some(false));
    let items = parsed.req("violations").as_arr().expect("violations array");
    assert_eq!(items.len(), 2);
    for item in items {
        assert_eq!(item.req("rule").as_str(), Some("serve-unwrap"));
        assert!(item.req("line").as_usize().is_some());
        assert!(item.req("path").as_str().is_some());
        assert!(item.req("snippet").as_str().is_some());
        assert!(item.req("detail").as_str().is_some());
    }
}
