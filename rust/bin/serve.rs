//! `aasvd-serve` — stand-alone HTTP front door over the synthetic
//! or dense backend.
//!
//! Boots the serving engine behind [`HttpServer`], prints the bound
//! address on stdout (one line, `listening <addr>`), then serves until
//! stdin reaches EOF or a `quit` line arrives — at which point it drains,
//! shuts down, and prints the merged [`ServeMetrics`] summary. Driving
//! stdin rather than signals keeps shutdown portable and scriptable:
//!
//! ```text
//! aasvd-serve --addr 127.0.0.1:8080 --step-delay-ms 20 &
//! ... drive it with aasvd-load --target 127.0.0.1:8080 ...
//! echo quit > /proc/<pid>/fd/0   # or close its stdin
//! ```
//!
//! `--serve dense` decodes through the real KV-cached forward pass over
//! randomly initialized dense weights (artifact-free, like the engine's
//! own tests), which is what lets `--kv-blocks` exercise the paged KV
//! pool and prefix cache over HTTP: an undersized pool sheds load with
//! 429s instead of growing without bound (see README "KV memory").
//! `--serve quantized` factors the same random-init weights exactly,
//! quantizes them to int8, and decodes through the fused-dequant kernels
//! (see README "Quantized serving") — paged KV works there too.

use aasvd::model::init::init_params;
use aasvd::model::lowrank::exact_factors;
use aasvd::model::quant_lowrank::QuantBlockFactors;
use aasvd::model::Config;
use aasvd::serve::{
    DecodeMode, DenseBackend, HttpOptions, HttpServer, ModelBackend, PagedKvOptions,
    QuantizedBackend, Server, ServerOptions, SyntheticBackend,
};
use aasvd::util::cli::Args;
use aasvd::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::io::BufRead;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse_env(
        "aasvd-serve: stand-alone HTTP front door, synthetic or dense (see README \"HTTP API\")",
    );
    let addr = args.str("addr", "127.0.0.1:0", "bind address (port 0 picks a free port)");
    let model = args.str("model", "small", "builtin config name");
    let serve = args.str(
        "serve",
        "synthetic",
        "backend: synthetic | dense | quantized (random-init weights)",
    );
    let seed = args.u64("seed", 0xa5_5eed, "weight-init seed for --serve dense/quantized");
    let step_delay_ms = args.f64("step-delay-ms", 0.0, "synthetic per-decode-tick delay");
    let prefill_delay_ms = args.f64("prefill-delay-ms", 0.0, "synthetic per-prefill delay");
    let max_queue = args.usize("max-queue", 4096, "admission queue bound");
    let max_batch = args.usize("max-batch", 4096, "decode-slot cap");
    let max_connections = args.usize("max-connections", 4096, "HTTP connection cap");
    let default_max_tokens = args.usize("default-max-tokens", 32, "max_tokens when omitted");
    let kv_blocks = args.usize("kv-blocks", 0, "paged KV pool size in blocks (0 = dense caches)");
    let kv_block_tokens = args.usize("kv-block-tokens", 16, "tokens per KV block");
    let no_prefix_cache = args.flag("no-prefix-cache", "disable radix prefix sharing when paged");
    args.finish_or_help();

    let cfg = Config::builtin(&model).ok_or_else(|| anyhow!("unknown builtin config '{model}'"))?;
    let backend_cfg = cfg.clone();
    let prefill_delay = Duration::from_secs_f64(prefill_delay_ms.max(0.0) / 1e3);
    let step_delay = Duration::from_secs_f64(step_delay_ms.max(0.0) / 1e3);
    let paged_kv = (kv_blocks > 0).then(|| PagedKvOptions {
        blocks: kv_blocks,
        block_tokens: kv_block_tokens.max(1),
        prefix_cache: !no_prefix_cache,
    });
    if paged_kv.is_some() && !matches!(serve.as_str(), "dense" | "quantized") {
        return Err(anyhow!(
            "--kv-blocks needs --serve dense or quantized (the synthetic backend has no KV cache to page)"
        ));
    }
    let server = Server::with_backend(
        cfg,
        ServerOptions {
            max_queue,
            max_batch,
            decode: DecodeMode::Cached,
            prefill_per_tick: 0,
            paged_kv,
            ..Default::default()
        },
        move || -> Result<Box<dyn ModelBackend>> {
            match serve.as_str() {
                "dense" => {
                    let params = init_params(&backend_cfg, &mut Rng::new(seed));
                    Ok(Box::new(DenseBackend::new(backend_cfg, params)))
                }
                "quantized" => {
                    let params = init_params(&backend_cfg, &mut Rng::new(seed));
                    let blocks = (0..backend_cfg.n_layers)
                        .map(|i| {
                            let bf = exact_factors(&backend_cfg, &params, i);
                            QuantBlockFactors::from_block(&backend_cfg, &bf)
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    Ok(Box::new(QuantizedBackend::new(backend_cfg, params, blocks)?))
                }
                "synthetic" => Ok(Box::new(SyntheticBackend::with_delays(
                    backend_cfg,
                    prefill_delay,
                    step_delay,
                ))),
                other => Err(anyhow!("unknown --serve backend '{other}'")),
            }
        },
    );
    let http = HttpServer::start(
        server,
        HttpOptions {
            addr,
            max_connections,
            default_max_tokens,
            ..Default::default()
        },
    )
    .context("start HTTP front door")?;
    println!("listening {}", http.addr());

    // serve until stdin closes or a `quit` line arrives
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        match line {
            Ok(l) if l.trim() == "quit" => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
    let metrics = http.shutdown();
    println!("{}", metrics.summary());
    Ok(())
}
