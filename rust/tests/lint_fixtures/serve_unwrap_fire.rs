// aasvd-lint: path=src/serve/fixture.rs

pub fn hot_path(v: &[f32]) -> f32 {
    let first = v.first().unwrap();
    let last = v.last().expect("nonempty");
    first + last
}
