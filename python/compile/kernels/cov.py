"""Layer-1 Pallas kernel: streaming covariance accumulation.

The covariance matrices S = B B^T and C = A B^T (paper Algorithm 1, step 2)
are the data-movement hot spot of AA-SVD's compression path: activations are
huge (l = N_cal * seq tokens) while the result is a fixed d x d matrix.

Hardware adaptation (paper used CUDA/cuBLAS outer-product streaming through
SMEM): we tile the token axis into VMEM-sized chunks with BlockSpec and keep
the C tile resident across the reduction axis of the grid — the output block
index_map ignores the token-grid coordinate, so Pallas revisits the same VMEM
tile while the MXU accumulates X_tile^T X_tile. HBM traffic is O(l*d) reads
plus a single O(d^2) write, instead of O(d^2 * l / l_tile) for a naive
blocked GEMM that spills partial sums.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical, and real-TPU efficiency is estimated
analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim: int, target: int = 128) -> int:
    """Largest divisor of `dim` that is <= target (VMEM tile sizing)."""
    for b in range(min(dim, target), 0, -1):
        if dim % b == 0:
            return b
    return dim


def _cov_kernel(c_ref, xi_ref, xj_ref, o_ref):
    """One (i, j, l) grid step: o[i,j] (+)= x_l[:, i]^T x_l[:, j]."""
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        xi_ref[...].T, xj_ref[...], preferred_element_type=jnp.float32
    )


def cov_accum(c, x, *, block_d: int | None = None, block_l: int | None = None,
              interpret: bool = True):
    """C + X^T X with X: [l, d] (rows = tokens), C: [d, d]."""
    return cross_cov_accum(c, x, x, block_d=block_d, block_l=block_l,
                           interpret=interpret)


def cross_cov_accum(c, a, b, *, block_d: int | None = None,
                    block_l: int | None = None, interpret: bool = True):
    """C + A^T B with A: [l, da], B: [l, db], C: [da, db].

    A == B gives the plain covariance; A = original activations X and
    B = shifted activations X' gives the anchored cross term.
    """
    l, da = a.shape
    _, db = b.shape
    assert c.shape == (da, db) and b.shape[0] == l
    bi = block_d or pick_block(da)
    bj = block_d or pick_block(db)
    bl = block_l or pick_block(l, 256)
    grid = (da // bi, db // bj, l // bl)
    return pl.pallas_call(
        _cov_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),   # C (init)
            pl.BlockSpec((bl, bi), lambda i, j, k: (k, i)),   # A tile
            pl.BlockSpec((bl, bj), lambda i, j, k: (k, j)),   # B tile
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((da, db), jnp.float32),
        interpret=interpret,
    )(c, a, b)
