//! Result-table rendering: aligned ASCII tables for stdout + JSON dumps
//! under results/, each row carrying the paper's reference numbers next to
//! our measured ones so the shape comparison is explicit.

use crate::util::json::Json;
use anyhow::Result;

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&format!("{}\n", "-".repeat(total)));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.headers
                        .iter()
                        .zip(r)
                        .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        Json::obj()
            .set("title", self.title.as_str())
            .set("rows", Json::Arr(rows))
    }

    /// Print to stdout and save under results/<id>.json.
    pub fn emit(&self, id: &str) -> Result<()> {
        println!("{}", self.render());
        crate::util::io::write_text(
            format!("results/{id}.json"),
            &self.to_json().to_string_pretty(),
        )
    }
}

/// Format helpers shared by the harnesses.
pub fn fmt_acc(x: f64) -> String {
    format!("{x:.2}")
}

pub fn fmt_drop(dense: f64, acc: f64) -> String {
    format!("{:.1}%", 100.0 * (dense - acc) / dense.max(1e-9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ppl"]);
        t.row(vec!["dense".into(), "5.68".into()]);
        t.row(vec!["aa_svd".into(), "6.89".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("dense"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let j = t.to_json();
        assert_eq!(
            j.req("rows").as_arr().unwrap()[0].req("b").as_str(),
            Some("2")
        );
    }

    #[test]
    fn drop_format() {
        assert_eq!(fmt_drop(0.55, 0.50), "9.1%");
    }
}
