//! Compression-path perf: covariance accumulation (Rust f64 vs the Pallas
//! cov_accum artifact through PJRT), the CompressLayer closed form at
//! `base` shapes, and the parallel hot path — chunked covariance
//! accumulation, a block's worth of fanned-out layer solves, and full
//! `compress_model` on a synthetic model via the artifact-free reference
//! collector — each at pinned 1-vs-4 worker counts. The threads=1 vs
//! threads=4 `compress_model` rows are the headline scaling record.

use aasvd::bench::Bench;
use aasvd::compress::{
    compress_layer, compress_model, CompressRun, CovTriple, Method, Objective,
    ReferenceCollector, RunOptions,
};
use aasvd::data::{Batcher, Corpus, Domain, TokenBatch};
use aasvd::model::Config;
use aasvd::runtime::{Engine, Value};
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;

/// Synthetic model for engine-free compression benches: big enough that
/// banded matmuls multi-thread, small enough for a CI smoke run.
fn synth_config() -> Config {
    Config {
        name: "synth".into(),
        vocab: 256,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 176,
        rope_theta: 10000.0,
        batch: 4,
        seq: 32,
        refine_batch: 8,
        train_batch: 8,
    }
}

fn full_batches(cfg: &Config, n: usize) -> Vec<TokenBatch> {
    let corpus = Corpus::generate(Domain::Wiki, 40_000, 17);
    Batcher::new(cfg.batch, cfg.seq)
        .sequential(&corpus.train, n)
        .into_iter()
        .filter(|b| b.real_rows == cfg.batch)
        .collect()
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(2);
    let d = 256usize;
    let chunk = 512usize;

    let x: Vec<f32> = (0..chunk * d).map(|_| rng.normal()).collect();
    let y: Vec<f32> = (0..chunk * d).map(|_| rng.normal()).collect();
    let flops = 3.0 * 2.0 * (chunk * d * d) as f64; // three accumulators

    b.run(
        &format!("cov_triple rust f64 d={d} chunk={chunk}"),
        Some(flops),
        || {
            let mut cov = CovTriple::new(d);
            cov.add_chunk(&x, &y);
            std::hint::black_box(cov);
        },
    );
    b.run(
        &format!("cov same-path rust f64 d={d} chunk={chunk}"),
        Some(flops / 3.0),
        || {
            let mut cov = CovTriple::new(d);
            cov.add_chunk_same(&x);
            std::hint::black_box(cov);
        },
    );

    // chunked parallel accumulation (the compress_model path): 8 chunks,
    // per-chunk partials merged in order — same result at every width
    {
        let chunks: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..chunk * d).map(|_| rng.normal()).collect())
            .collect();
        let views: Vec<&[f32]> = chunks.iter().map(|c| c.as_slice()).collect();
        let total_flops = 2.0 * (8 * chunk * d * d) as f64;
        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(
                &format!("cov accumulate 8 chunks d={d} threads={threads}"),
                Some(total_flops),
                || {
                    std::hint::black_box(CovTriple::accumulate_same(&pool, d, &views));
                },
            );
        }
    }

    // Pallas kernel through PJRT (includes literal transfer per call)
    if let Ok(engine) = Engine::new("artifacts") {
        if engine.entry("base").is_ok() {
            let chunk_k = engine.entry("base").unwrap().cov_chunk;
            let xk: Vec<f32> = (0..chunk_k * d).map(|_| rng.normal()).collect();
            let c = vec![0f32; d * d];
            engine.warmup("base", &["cov_accum_d"]).unwrap();
            b.run(
                &format!("cov pallas/pjrt d={d} chunk={chunk_k}"),
                Some(2.0 * (chunk_k * d * d) as f64),
                || {
                    std::hint::black_box(
                        engine
                            .run("base", "cov_accum_d", &[Value::F32(&c), Value::F32(&xk)])
                            .unwrap(),
                    );
                },
            );
        }
    }

    // full CompressLayer closed form at base attention / MLP shapes
    for (m, n, k) in [(256usize, 256usize, 85usize), (704, 256, 128)] {
        let w: Vec<f32> = (0..m * n).map(|_| rng.normal() * 0.02).collect();
        let a: Vec<f32> = (0..4 * n * n).map(|_| rng.normal()).collect();
        let mut cov = CovTriple::new(n);
        cov.add_chunk_same(&a);
        cov.mirror_same();
        let (c, s) = Objective::Anchored.assemble(&cov).unwrap();
        b.run(&format!("compress_layer {m}x{n} k={k}"), None, || {
            std::hint::black_box(compress_layer(&w, m, n, &c, &s, k));
        });
    }

    // a block's worth of independent layer solves (the q/k/v/o/up/down
    // fan-out inside compress_model) at pinned widths; each solve pins
    // its inner linalg to one thread so the job-level scaling is clean
    {
        let shapes: [(usize, usize, usize); 7] = [
            (256, 256, 85),
            (256, 256, 85),
            (256, 256, 85),
            (256, 256, 85),
            (704, 256, 128),
            (704, 256, 128),
            (256, 704, 85),
        ];
        let weights: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&(m, n, _)| (0..m * n).map(|_| rng.normal() * 0.02).collect())
            .collect();
        let mut covs = Vec::new();
        for dim in [256usize, 704] {
            let a: Vec<f32> = (0..2 * dim * dim).map(|_| rng.normal()).collect();
            let mut cov = CovTriple::new(dim);
            cov.add_chunk_same(&a);
            cov.mirror_same();
            covs.push((dim, Objective::Anchored.assemble(&cov).unwrap()));
        }
        let jobs_input: Vec<_> = shapes
            .iter()
            .zip(&weights)
            .map(|(&(m, n, k), w)| {
                let cs = covs
                    .iter()
                    .find(|(dim, _)| *dim == n)
                    .map(|(_, cs)| cs)
                    .expect("cov for dim");
                (m, n, k, w.as_slice(), cs)
            })
            .collect();
        for threads in [1usize, 4] {
            let pool = Pool::exact(threads);
            b.run(&format!("block solve fan-out 7 linears threads={threads}"), None, || {
                let solved = pool.run(
                    jobs_input
                        .iter()
                        .map(|&(m, n, k, w, cs)| {
                            move || {
                                Pool::exact(1)
                                    .install(|| compress_layer(w, m, n, &cs.0, &cs.1, k))
                            }
                        })
                        .collect(),
                );
                std::hint::black_box(solved);
            });
        }
    }

    // the headline: full Algorithm 2 on the synthetic model through the
    // artifact-free reference collector, 1 vs 4 workers. Artifacts are
    // identical across widths (enforced by tests/parallel_determinism.rs);
    // only the wall clock moves.
    {
        let cfg = synth_config();
        let params = aasvd::model::init::init_params(&cfg, &mut Rng::new(5));
        let calib = full_batches(&cfg, 4);
        assert!(calib.len() >= 2, "synthetic calib too small");
        for threads in [1usize, 4] {
            let method = Method::builder(format!("anchored_t{threads}"))
                .objective(Objective::Anchored)
                .threads(threads)
                .build();
            b.run(
                &format!("compress_model ref synth anchored threads={threads}"),
                None,
                || {
                    std::hint::black_box(
                        compress_model(
                            &ReferenceCollector,
                            &cfg,
                            &params,
                            &calib,
                            &method,
                            0.6,
                        )
                        .unwrap(),
                    );
                },
            );
        }
    }

    // the streaming, checkpointed session: same Algorithm 2, but every
    // block is committed to a run directory (shard + stream snapshot +
    // manifest, each atomic) as it completes. The delta vs compress_model
    // threads=4 above is the checkpoint overhead.
    {
        let cfg = synth_config();
        let params = aasvd::model::init::init_params(&cfg, &mut Rng::new(5));
        let calib = full_batches(&cfg, 4);
        let method = Method::builder("anchored_stream")
            .objective(Objective::Anchored)
            .threads(4)
            .build();
        let dir = std::env::temp_dir().join("aasvd-bench-compress-run");
        let stream_once = || {
            let _ = std::fs::remove_dir_all(&dir);
            let mut run = CompressRun::new(
                &ReferenceCollector,
                &cfg,
                &params,
                &calib,
                &method,
                0.6,
                RunOptions::checkpointed(&dir),
            )
            .unwrap();
            while run.next_block().unwrap().is_some() {}
            run.finish().unwrap()
        };
        // pre-flight: the streamed artifact must decode to the same bits
        // compress_model produces in memory
        let summary = stream_once();
        let streamed = aasvd::model::lowrank::load_blocks(
            &cfg,
            summary.artifact.as_ref().expect("streamed artifact"),
        )
        .unwrap();
        let inmem = compress_model(&ReferenceCollector, &cfg, &params, &calib, &method, 0.6)
            .unwrap();
        for (a, b) in streamed.iter().zip(&inmem.blocks) {
            assert_eq!(a.factors.data, b.factors.data, "stream/in-memory divergence");
            assert_eq!(a.masks.data, b.masks.data, "stream/in-memory divergence");
        }
        b.run(
            "compress_run stream+checkpoint synth threads=4",
            None,
            || {
                std::hint::black_box(stream_once());
            },
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    b.save("compress");
}
