// aasvd-lint: path=src/serve/http/fixture.rs

pub fn first_header(headers: &[(String, String)]) -> &str {
    headers.first().unwrap().1.as_str()
}
