//! `aasvd` — the leader CLI: pretrain, compress, evaluate and serve models
//! through the three-layer runtime.
//!
//! Subcommands:
//!   pretrain  --config base [--steps N]            train + checkpoint
//!   compress  --config base --method aa_svd --ratio 0.6 [--out path]
//!             checkpointed + resumable: every solved block lands in a run
//!             directory (--run-dir, default <out>.run); --resume continues
//!             an interrupted run bitwise-identically, --status reports a
//!             run directory's progress, --json emits a machine summary,
//!             --synthetic runs artifact-free on a builtin config
//!   eval      --config base [--compressed path]    PPL + zero-shot battery
//!   generate  --config base --prompt "..."         decode via the server
//!   info                                           manifest + configs

use aasvd::compress::{Collector, CompressRun, Method, RunOptions};
use aasvd::data::TokenBatch;
use aasvd::eval::{all_tasks_accuracy, compressed_ppl, dense_ppl, display_ppl, ModelRef, Table};
use aasvd::experiments::{setup, Knobs};
use aasvd::model::lowrank::{load_blocks, BlockFactors};
use aasvd::model::quant_lowrank::load_quant_blocks;
use aasvd::model::{Config, FlatStore};
use aasvd::refine::RefineOptions;
use aasvd::runtime::{BlockStatus, Engine, RunManifest};
use aasvd::serve::{Event, GenParams, ServedModel, Server};
use aasvd::util::cli::Args;
use aasvd::util::json::Json;
use anyhow::{bail, Result};
use std::io::Write;

fn main() -> Result<()> {
    let args = Args::parse_env(
        "AA-SVD coordinator: anchored & adaptive SVD compression of LLMs",
    );
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: aasvd <pretrain|compress|eval|generate|info> [flags]\n\
                 run with --help after a subcommand for flags"
            );
            Ok(())
        }
    }
}

/// Resolve a method name. `refine` is `None` when no engine is available
/// (the synthetic path): methods that *require* refinement are refused
/// there, and bare-objective ablation names resolve without it.
pub fn method_by_name(name: &str, refine: Option<RefineOptions>) -> Result<Method> {
    Ok(match name {
        "naive_svd" => Method::naive_svd(),
        "asvd" => Method::asvd(),
        "svd_llm" => Method::svd_llm(),
        "dobi" => Method::dobi(),
        "dobi_q" => Method::dobi_q(),
        "aa_svd" | "aa_svd_q" => {
            let Some(r) = refine else {
                bail!(
                    "method '{name}' includes block refinement, which drives \
                     the AOT refine_step artifact and is unavailable here — \
                     pick a refinement-free method (e.g. anchored, svd_llm)"
                );
            };
            if name == "aa_svd" {
                Method::aa_svd(r)
            } else {
                Method::aa_svd_q(r)
            }
        }
        other => match aasvd::compress::Objective::from_name(other) {
            Some(o) => Method::ablation(o, refine),
            None => bail!("unknown method '{other}'"),
        },
    })
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let steps = args.usize("steps", knobs.pretrain_steps, "training steps");
    args.finish_or_help();
    let engine = Engine::new("artifacts")?;
    let cfg = engine.entry(&knobs.config)?.config.clone();
    let (params, result) = aasvd::train::pretrain(
        &engine,
        &cfg,
        &aasvd::train::PretrainOptions {
            steps,
            ..Default::default()
        },
    )?;
    std::fs::create_dir_all("checkpoints")?;
    let path = aasvd::train::pretrain::checkpoint_path(&cfg);
    params.save(&path)?;
    aasvd::train::pretrain::save_loss_curve(
        &result,
        &format!("checkpoints/{}_loss.json", cfg.name),
    )?;
    println!(
        "pretrained '{}' for {steps} steps: loss {:.3} -> {:.3} ({:.0}s, {} tokens) -> {path}",
        cfg.name,
        result.losses.first().map(|x| x.1).unwrap_or(0.0),
        result.final_loss,
        result.secs,
        result.tokens_seen
    );
    Ok(())
}

/// Flags shared by both compress paths (engine-backed and synthetic).
struct CompressCli {
    ratio: f64,
    out: String,
    run_dir: String,
    resume: bool,
    json: bool,
    crash_after: Option<usize>,
}

fn cmd_compress(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let method_name = args.str("method", "aa_svd", "compression method");
    let ratio = args.f64("ratio", 0.6, "parameter ratio");
    let out = args.str(
        "out",
        &format!("checkpoints/{}_{}_{}.aat", knobs.config, method_name, ratio),
        "output artifact path",
    );
    let run_dir = args.str("run-dir", &format!("{out}.run"), "checkpoint directory");
    let resume = args.flag("resume", "continue an interrupted run from its checkpoints");
    let status = args.flag("status", "report the run directory's progress and exit");
    let json = args.flag("json", "emit the summary as JSON on stdout");
    let synthetic = args.flag(
        "synthetic",
        "artifact-free: builtin config, generated weights/data, reference collector",
    );
    let seed = args.u64("seed", 3, "synthetic weight-init seed");
    let crash_after = args.str(
        "crash-after-block",
        "",
        "abort() right after this block commits (crash testing)",
    );
    args.finish_or_help();

    let crash_after: Option<usize> = match crash_after.as_str() {
        "" => None,
        s => Some(s.parse().map_err(|_| {
            anyhow::anyhow!("--crash-after-block expects a block index, got '{s}'")
        })?),
    };
    if status {
        return compress_status(&run_dir, json);
    }
    let cli = CompressCli {
        ratio,
        out,
        run_dir,
        resume,
        json,
        crash_after,
    };

    if synthetic {
        aasvd::util::pool::set_global_threads(knobs.threads);
        let Some(cfg) = Config::builtin(&knobs.config) else {
            bail!(
                "--synthetic needs a builtin config and '{}' is not one",
                knobs.config
            );
        };
        let params = aasvd::model::init::init_params(
            &cfg,
            &mut aasvd::util::rng::Rng::new(seed),
        );
        let n_batches = (knobs.calib_seqs / cfg.batch).max(1);
        let bytes = (n_batches * cfg.batch * (cfg.seq + 1) * 4).max(40_000);
        let corpus = aasvd::data::Corpus::generate(aasvd::data::Domain::Wiki, bytes, 42);
        let calib: Vec<TokenBatch> = aasvd::data::Batcher::new(cfg.batch, cfg.seq)
            .sequential(&corpus.train, n_batches)
            .into_iter()
            .filter(|b| b.real_rows == cfg.batch)
            .collect();
        let method = method_by_name(&method_name, None)?;
        return run_compress(
            &cli,
            &aasvd::compress::ReferenceCollector,
            &cfg,
            &params,
            &calib,
            &method,
        );
    }

    let ctx = setup(&knobs)?;
    let method = method_by_name(&method_name, Some(knobs.refine()))?;
    run_compress(&cli, &ctx.engine, &ctx.cfg, &ctx.params, &ctx.calib, &method)
}

/// Drive a checkpointed [`CompressRun`] to completion, pacing the block
/// loop from here so progress is visible and crash injection lands at a
/// deterministic point.
fn run_compress<C: Collector>(
    cli: &CompressCli,
    collector: &C,
    cfg: &Config,
    params: &FlatStore,
    calib: &[TokenBatch],
    method: &Method,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let mut options = RunOptions::checkpointed(&cli.run_dir).artifact(&cli.out);
    if cli.resume {
        options = options.resume();
    }
    let mut run = CompressRun::new(collector, cfg, params, calib, method, cli.ratio, options)?;
    if run.resumed_blocks() > 0 {
        eprintln!(
            "resuming at block {}/{} from {}",
            run.resumed_blocks(),
            run.total_blocks(),
            cli.run_dir
        );
    }
    while let Some(done) = run.next_block()? {
        eprintln!(
            "block {}/{} solved in {:.1}s",
            done.index + 1,
            done.total,
            done.secs
        );
        if cli.crash_after == Some(done.index) {
            eprintln!("--crash-after-block {}: aborting mid-run", done.index);
            std::process::abort();
        }
    }
    let summary = run.finish()?;
    let wall = t0.elapsed().as_secs_f64();
    let peak_mb = aasvd::util::mem::peak_rss_mb();
    // quantized methods store int8 factors + scales, so report the ratio
    // of what the artifact actually holds, not its f32-equivalent size
    let achieved_ratio = if method.quantized() {
        summary.allocation.achieved_ratio_quantized(cfg)
    } else {
        summary.allocation.achieved_ratio(cfg)
    };
    let artifact = summary
        .artifact
        .as_ref()
        .map(|p| p.display().to_string())
        .unwrap_or_default();
    if cli.json {
        let j = Json::obj()
            .set("config", cfg.name.as_str())
            .set("method", method.name.as_str())
            .set("ratio", cli.ratio)
            .set("blocks_total", summary.total)
            .set("blocks_solved", summary.solved)
            .set("blocks_resumed", summary.resumed)
            .set("blocks_skipped", summary.skipped)
            .set("achieved_ratio", achieved_ratio)
            .set("secs_wall", wall)
            .set("secs_collect", summary.report.secs_collect)
            .set("secs_solve", summary.report.secs_solve)
            .set("secs_refine", summary.report.secs_refine)
            .set("peak_rss_mb", peak_mb)
            .set("artifact", artifact.as_str())
            .set(
                "artifact_hash",
                summary
                    .artifact_hash
                    .map(aasvd::util::hash::to_hex)
                    .unwrap_or_default(),
            )
            .set("run_dir", cli.run_dir.as_str());
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "compressed '{}' with {} @ {} in {wall:.1}s on {} threads \
             (collect {:.1}s, solve {:.1}s, refine {:.1}s; peak rss {peak_mb:.0} MB)",
            cfg.name,
            method.name,
            cli.ratio,
            aasvd::util::pool::auto_threads(),
            summary.report.secs_collect,
            summary.report.secs_solve,
            summary.report.secs_refine,
        );
        println!(
            "blocks: {} solved, {} resumed, {} skipped of {} -> {artifact}",
            summary.solved, summary.resumed, summary.skipped, summary.total
        );
        println!(
            "achieved parameter ratio: {:.3}{} (per-linear ranks: {:?})",
            achieved_ratio,
            if method.quantized() { " (int8 + scales)" } else { "" },
            summary.allocation.ranks
        );
    }
    Ok(())
}

/// `compress --status`: report a run directory's checkpoint progress.
fn compress_status(run_dir: &str, json: bool) -> Result<()> {
    let path = std::path::Path::new(run_dir).join("run.json");
    let m = RunManifest::load(&path)?;
    let written = m
        .blocks
        .iter()
        .filter(|b| b.status == BlockStatus::Written)
        .count();
    let next = m.first_unwritten();
    if json {
        let j = Json::obj()
            .set("config", m.config.as_str())
            .set("method", m.method.as_str())
            .set("ratio", m.ratio)
            .set("complete", m.complete)
            .set("blocks_total", m.blocks.len())
            .set("blocks_written", written)
            .set("next_block", next.map(|i| i as i64).unwrap_or(-1));
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "run {run_dir}: config '{}' method '{}' ratio {} — {written}/{} blocks written{}",
            m.config,
            m.method,
            m.ratio,
            m.blocks.len(),
            if m.complete { ", complete" } else { "" },
        );
        if let Some(i) = next {
            println!("next block to solve: {i} (pass --resume to continue)");
        }
    }
    Ok(())
}

/// Whether a compress artifact holds int8 quantized factors (AAT2
/// layout from a quantized method) rather than f32 low-rank factors
/// (AAT1). Decided by the archive magic, not the method name, so
/// renamed artifacts still load correctly.
fn artifact_is_quantized(path: &str) -> Result<bool> {
    use std::io::Read;
    let mut magic = [0u8; 4];
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening artifact {path}: {e}"))?;
    f.read_exact(&mut magic)
        .map_err(|e| anyhow::anyhow!("reading artifact magic of {path}: {e}"))?;
    Ok(&magic == b"AAT2")
}

/// Load either artifact flavor as f32 block factors for evaluation.
/// Quantized artifacts dequantize through `to_block`, so the evaluated
/// weights are bit-for-bit the ones the fused int8 kernels compute with.
fn load_blocks_any(cfg: &Config, path: &str) -> Result<(Vec<BlockFactors>, bool)> {
    if artifact_is_quantized(path)? {
        let qblocks = load_quant_blocks(cfg, path)?;
        Ok((qblocks.iter().map(|qb| qb.to_block(cfg)).collect(), true))
    } else {
        Ok((load_blocks(cfg, path)?, false))
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let compressed = args.str("compressed", "", "path to compressed blocks (.aat)");
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let (blocks, quantized) = if compressed.is_empty() {
        (None, false)
    } else {
        let (b, q) = load_blocks_any(&ctx.cfg, &compressed)?;
        (Some(b), q)
    };
    let mut table = Table::new(
        &format!(
            "eval — {} {}",
            knobs.config,
            match (&blocks, quantized) {
                (None, _) => "(dense)",
                (Some(_), false) => "(compressed)",
                (Some(_), true) => "(compressed, int8)",
            }
        ),
        &["metric", "value"],
    );
    for (domain, batches) in &ctx.eval {
        let ppl = match &blocks {
            None => dense_ppl(&ctx.engine, &ctx.cfg, &ctx.params, batches)?,
            Some(b) => compressed_ppl(&ctx.engine, &ctx.cfg, &ctx.params, b, batches)?,
        };
        table.row(vec![format!("ppl/{}", domain.name()), display_ppl(ppl)]);
    }
    let model_ref = match &blocks {
        None => ModelRef::Dense(&ctx.params),
        Some(b) => ModelRef::Compressed(&ctx.params, b),
    };
    let (per_task, avg) = all_tasks_accuracy(
        &ctx.engine,
        &ctx.cfg,
        &model_ref,
        ctx.n_task_instances,
        ctx.task_seed,
    )?;
    for (task, acc) in per_task {
        table.row(vec![format!("acc/{}", task.name()), format!("{acc:.3}")]);
    }
    table.row(vec!["acc/avg".into(), format!("{avg:.3}")]);
    println!("{}", table.render());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let knobs = Knobs::parse(args, "base");
    let prompt = args.str("prompt", "the cat", "prompt text");
    let max_new = args.usize("max-new", 48, "tokens to generate");
    let temp = args.f64("temperature", 0.0, "sampling temperature") as f32;
    let compressed = args.str("compressed", "", "compressed blocks (.aat)");
    args.finish_or_help();
    let ctx = setup(&knobs)?;
    let model = if compressed.is_empty() {
        ServedModel::Dense(ctx.params.clone())
    } else if artifact_is_quantized(&compressed)? {
        // decode through the fused int8 kernels, not a dequantized copy
        ServedModel::Quantized(ctx.params.clone(), load_quant_blocks(&ctx.cfg, &compressed)?)
    } else {
        ServedModel::Compressed(ctx.params.clone(), load_blocks(&ctx.cfg, &compressed)?)
    };
    let server = Server::start(ctx.cfg.clone(), model);
    let completion = server
        .submit(
            &prompt,
            GenParams {
                max_new_tokens: max_new,
                temperature: temp,
                ..Default::default()
            },
        )
        .map_err(|e| anyhow::anyhow!("submit failed: {e}"))?;
    print!("{prompt}│");
    std::io::stdout().flush()?;
    let resp = loop {
        match completion.next_event() {
            Some(Event::Token(t)) => {
                print!("{}", t.ch);
                std::io::stdout().flush()?;
            }
            Some(Event::Done(resp)) => break resp,
            Some(Event::Cancelled { reason, .. }) => {
                println!();
                bail!("request retired: {reason}");
            }
            None => bail!("serve worker went away mid-request"),
        }
    };
    println!();
    println!(
        "[{} tokens, ttft {:.0} ms, total {:.0} ms]",
        resp.tokens_generated,
        resp.ttft * 1e3,
        resp.latency * 1e3
    );
    drop(completion);
    server.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("artifact dir: {}", engine.manifest.dir.display());
    for (name, entry) in &engine.manifest.configs {
        println!(
            "config '{name}': d={} heads={} layers={} ff={} vocab={} \
             params={} artifacts={}",
            entry.config.d_model,
            entry.config.n_heads,
            entry.config.n_layers,
            entry.config.d_ff,
            entry.config.vocab,
            entry.param_layout.total,
            entry.artifacts.len()
        );
    }
    Ok(())
}
