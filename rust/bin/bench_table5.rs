//! Table 5 (ablation): the four layer-wise objectives × block refinement.
//!
//! Paper: LLaMA-7B at ratios 0.8/0.6 — input-agnostic degenerates without
//! refinement, refinement rescues everything, input-aware + refinement is
//! best overall, and final quality stays sensitive to the initialization
//! objective.

use aasvd::compress::{BlockOutcome, Method, ALL_OBJECTIVES};
use aasvd::data::Domain;
use aasvd::eval::{display_ppl, Table};
use aasvd::experiments::{eval_compressed_method_observed, eval_dense, setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;

/// Paper Table 5: (ratio, objective, refined, ppl, acc).
const PAPER: [(f64, &str, bool, f64, f64); 16] = [
    (0.8, "input_agnostic", false, 2e4, 0.31),
    (0.8, "input_agnostic", true, 7.35, 0.50),
    (0.8, "input_aware", false, 7.89, 0.45),
    (0.8, "input_aware", true, 6.89, 0.50),
    (0.8, "shift_aware", false, 8.22, 0.45),
    (0.8, "shift_aware", true, 7.28, 0.45),
    (0.8, "anchored", false, 7.68, 0.46),
    (0.8, "anchored", true, 7.08, 0.48),
    (0.6, "input_agnostic", false, 5e5, 0.30),
    (0.6, "input_agnostic", true, 10.93, 0.45),
    (0.6, "input_aware", false, 13.11, 0.37),
    (0.6, "input_aware", true, 8.35, 0.44),
    (0.6, "shift_aware", false, 14.87, 0.36),
    (0.6, "shift_aware", true, 8.54, 0.44),
    (0.6, "anchored", false, 12.19, 0.38),
    (0.6, "anchored", true, 8.52, 0.43),
];

fn main() -> Result<()> {
    let args = Args::parse_env("Table 5: objective x refinement ablation");
    let mut knobs = Knobs::parse(&args, "small");
    knobs.ratios = args
        .list("ratios", "0.8,0.6", "ratios")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    args.finish_or_help();
    let ctx = setup(&knobs)?;

    let dense = eval_dense(&ctx)?;
    let mut table = Table::new(
        "Table 5 — layer-wise objective × block refinement",
        &["ratio", "objective", "refine", "ppl", "acc", "paper:ppl", "paper:acc"],
    );
    table.row(vec![
        "1.0".into(),
        "dense".into(),
        "-".into(),
        display_ppl(dense.ppl_of(Domain::Wiki)),
        format!("{:.3}", dense.avg_acc),
        "5.68".into(),
        "0.55".into(),
    ]);

    for &ratio in &knobs.ratios {
        for objective in ALL_OBJECTIVES {
            for refined in [false, true] {
                let method = Method::ablation(
                    objective,
                    refined.then(|| knobs.refine()),
                );
                let (ev, _) = eval_compressed_method_observed(
                    &ctx,
                    &method,
                    ratio,
                    &mut |o: &BlockOutcome| {
                        eprintln!(
                            "[table5] {} @ {ratio}: block {}/{} ({:.1}s)",
                            method.name,
                            o.index + 1,
                            o.total,
                            o.secs
                        );
                    },
                )?;
                let paper = PAPER
                    .iter()
                    .find(|(r, o, rf, ..)| {
                        *r == ratio && *o == objective.name() && *rf == refined
                    })
                    .map(|&(_, _, _, p, a)| (display_ppl(p), format!("{a:.2}")))
                    .unwrap_or(("-".into(), "-".into()));
                table.row(vec![
                    format!("{ratio}"),
                    objective.name().into(),
                    if refined { "yes" } else { "no" }.into(),
                    display_ppl(ev.ppl_of(Domain::Wiki)),
                    format!("{:.3}", ev.avg_acc),
                    paper.0,
                    paper.1,
                ]);
            }
        }
    }
    table.emit("table5")?;
    Ok(())
}
