// aasvd-lint: path=src/linalg/fixture.rs

pub fn timed() -> f64 {
    // aasvd-lint: allow(wallclock)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
