//! Paged KV storage: fixed-size KV blocks drawn from a bounded pool.
//!
//! Dense serving stores each session's KV rows in one contiguous
//! [`KvCache`](super::forward::KvCache) that grows without bound. Paged
//! storage instead chains fixed-size blocks (`block_tokens` rows each)
//! behind the same [`KvSeq`]/[`KvSeqStore`] traits the step kernels walk,
//! so:
//!
//! - total KV memory is hard-bounded by the pool's block budget
//!   ([`KvBlockPool::try_alloc`] fails with [`KvPressure`] instead of
//!   growing), and
//! - sessions whose prompts share a token prefix can alias the *same*
//!   `Arc<KvBlock>`s for the shared span (the radix prefix cache in
//!   `serve::kv_pool` builds on this).
//!
//! ## Bitwise contract
//!
//! Paging changes only *where* a KV row lives, never a float operation or
//! its order: [`PagedLayer`] hands the kernels the same contiguous
//! `[d_model]` row slices a dense `LayerKv` would, and the kernels
//! themselves are shared generics. Shared-prefix reuse is bitwise-safe
//! because RoPE'd keys depend only on the absolute position and the token
//! — identical prefixes produce identical block contents, so aliasing a
//! block is indistinguishable from recomputing it.
//!
//! ## Copy-on-write discipline
//!
//! Shared blocks are never written. Only *full* blocks are ever published
//! for sharing, and [`PagedLayer::push_row`] appends only to the tail
//! block, which is either freshly allocated or was filled by this session
//! — uniquely owned either way. `Arc::get_mut` enforces this at runtime:
//! a write to an aliased block is a panic, not a silent corruption.
//!
//! ## Accounting
//!
//! Every block carries a [`Permit`] whose `Drop` returns it to the pool's
//! atomic residency counter, so `in_use` tracks live blocks exactly no
//! matter which session, trie node, or in-flight error path drops the
//! last `Arc`. After a full drain (sessions retired, prefix cache
//! cleared) `in_use` returning to zero is the no-leak invariant the
//! engine fuzz suite asserts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::forward::{KvSeq, KvSeqStore};

/// The pool cannot supply the requested blocks without exceeding its
/// budget. Carries enough context for admission control and operator
/// logs; the engine maps it to a 429 at the HTTP front door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPressure {
    /// Blocks the failed request needed.
    pub needed: usize,
    /// The pool's total block budget.
    pub capacity: usize,
    /// Blocks resident when the request failed.
    pub in_use: usize,
}

impl std::fmt::Display for KvPressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv pool pressure: need {} block(s), {}/{} in use",
            self.needed, self.in_use, self.capacity
        )
    }
}

impl std::error::Error for KvPressure {}

/// Shared residency counters for one pool. `in_use` is incremented by
/// [`KvBlockPool::try_alloc`] and decremented by [`Permit::drop`]; `peak`
/// is the high-water mark of `in_use`.
#[derive(Debug)]
struct PoolCounters {
    capacity: usize,
    in_use: AtomicUsize,
    peak: AtomicUsize,
}

/// Drop-guard tying one block's lifetime to the pool residency count.
#[derive(Debug)]
pub struct Permit {
    counters: Arc<PoolCounters>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.counters.in_use.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One fixed-size KV block: up to `block_tokens` RoPE'd key rows and raw
/// value rows for a single layer, plus the pool permit that frees its
/// budget slot when the last owner drops it. Blocks are handed out as
/// `Arc<KvBlock>` so prefix-sharing is an `Arc::clone`, and mutation is
/// only possible while uniquely owned (`Arc::get_mut`).
#[derive(Debug)]
pub struct KvBlock {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    _permit: Permit,
}

/// Bounded allocator of [`KvBlock`]s. Cloning the pool handle shares the
/// same budget and counters.
#[derive(Clone, Debug)]
pub struct KvBlockPool {
    counters: Arc<PoolCounters>,
    block_tokens: usize,
    d_model: usize,
}

impl KvBlockPool {
    /// A pool of at most `blocks` blocks, each holding `block_tokens`
    /// rows of width `d_model`.
    pub fn new(blocks: usize, block_tokens: usize, d_model: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        assert!(d_model > 0, "d_model must be positive");
        KvBlockPool {
            counters: Arc::new(PoolCounters {
                capacity: blocks,
                in_use: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
            }),
            block_tokens,
            d_model,
        }
    }

    /// Allocate one empty block, or fail with [`KvPressure`] if the pool
    /// is at budget. Never blocks and never over-allocates: the
    /// increment-if-below-capacity is a single atomic `fetch_update`.
    pub fn try_alloc(&self) -> Result<Arc<KvBlock>, KvPressure> {
        let c = &self.counters;
        match c.in_use.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            if n < c.capacity {
                Some(n + 1)
            } else {
                None
            }
        }) {
            Ok(prev) => {
                c.peak.fetch_max(prev + 1, Ordering::SeqCst);
                let floats = self.block_tokens * self.d_model;
                Ok(Arc::new(KvBlock {
                    k: Vec::with_capacity(floats),
                    v: Vec::with_capacity(floats),
                    _permit: Permit {
                        counters: Arc::clone(c),
                    },
                }))
            }
            Err(at_cap) => Err(KvPressure {
                needed: 1,
                capacity: c.capacity,
                in_use: at_cap,
            }),
        }
    }

    /// Rows per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Total block budget.
    pub fn capacity(&self) -> usize {
        self.counters.capacity
    }

    /// Blocks currently resident (live `Arc<KvBlock>`s anywhere).
    pub fn in_use(&self) -> usize {
        self.counters.in_use.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::in_use`] since the pool was created.
    pub fn peak(&self) -> usize {
        self.counters.peak.load(Ordering::SeqCst)
    }

    /// Bytes one fully-populated block occupies (k + v payload).
    pub fn block_bytes(&self) -> usize {
        2 * self.block_tokens * self.d_model * std::mem::size_of::<f32>()
    }
}

/// One layer's KV rows for one session, chained across pool blocks.
/// Prefix-shared blocks (always full) may be aliased by other sessions
/// or the prefix trie; the partial tail block is always uniquely owned.
#[derive(Debug, Default)]
pub struct PagedLayer {
    pub blocks: Vec<Arc<KvBlock>>,
    rows: usize,
    block_tokens: usize,
}

impl PagedLayer {
    fn new(block_tokens: usize) -> Self {
        PagedLayer {
            blocks: Vec::new(),
            rows: 0,
            block_tokens,
        }
    }

    /// Rows currently stored.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Whether appending one more row requires a fresh block first.
    fn tail_full(&self) -> bool {
        self.rows == self.blocks.len() * self.block_tokens
    }

    /// Seed this layer with already-shared full prefix blocks. Only valid
    /// on an empty layer, and every block must be full — partial blocks
    /// are never shared, so each one contributes exactly `block_tokens`
    /// rows.
    pub fn adopt_prefix(&mut self, blocks: &[Arc<KvBlock>]) {
        assert_eq!(self.rows, 0, "adopt_prefix on a non-empty layer");
        if blocks.is_empty() {
            return;
        }
        let floats = blocks[0].k.len();
        for b in blocks {
            assert_eq!(b.k.len(), floats, "prefix blocks must all be full");
            self.blocks.push(Arc::clone(b));
        }
        self.rows = blocks.len() * self.block_tokens;
    }
}

impl KvSeq for PagedLayer {
    fn seq_rows(&self, _d: usize) -> usize {
        self.rows
    }

    fn push_row(&mut self, k: &[f32], v: &[f32]) {
        let bi = self.rows / self.block_tokens;
        assert!(
            bi < self.blocks.len(),
            "push_row without a reserved tail block (reserve_append first)"
        );
        let tail = Arc::get_mut(&mut self.blocks[bi])
            .expect("paged tail block is uniquely owned (shared blocks are never written)");
        tail.k.extend_from_slice(k);
        tail.v.extend_from_slice(v);
        self.rows += 1;
    }

    fn k_row(&self, j: usize, d: usize) -> &[f32] {
        let bt = self.block_tokens;
        let r = j % bt;
        &self.blocks[j / bt].k[r * d..(r + 1) * d]
    }

    fn v_row(&self, j: usize, d: usize) -> &[f32] {
        let bt = self.block_tokens;
        let r = j % bt;
        &self.blocks[j / bt].v[r * d..(r + 1) * d]
    }
}

/// A session's full KV state on paged storage: one [`PagedLayer`] per
/// transformer block plus the absorbed-position count. Drop-in
/// [`KvSeqStore`] twin of [`KvCache`](super::forward::KvCache).
///
/// Deliberately not `Clone`: cloning would alias partial tail blocks,
/// breaking the unique-tail invariant `push_row` relies on. Sharing
/// happens only through full prefix blocks via [`PagedLayer::adopt_prefix`].
#[derive(Debug)]
pub struct PagedKvCache {
    pub layers: Vec<PagedLayer>,
    pub len: usize,
    block_tokens: usize,
}

impl PagedKvCache {
    pub fn new(n_layers: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        PagedKvCache {
            layers: (0..n_layers).map(|_| PagedLayer::new(block_tokens)).collect(),
            len: 0,
            block_tokens,
        }
    }

    /// Rows per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks referenced by this session across all layers (shared prefix
    /// blocks count once per referencing session).
    pub fn blocks_referenced(&self) -> usize {
        self.layers.iter().map(|l| l.blocks.len()).sum()
    }

    /// Payload bytes stored for this session (k + v rows actually
    /// written, matching `KvCache::bytes` semantics for the dense twin;
    /// shared prefix rows count toward every referencing session).
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.blocks.iter().map(|b| (b.k.len() + b.v.len()) * 4).sum::<usize>())
            .sum()
    }

    /// Ensure every layer's tail block has room for one more row,
    /// allocating through `alloc` where needed. Must be called before
    /// each single-position step on this cache (and therefore outside the
    /// banded kernels — allocation never happens on a worker thread).
    ///
    /// On failure the cache is left consistent: layers that already got a
    /// fresh tail keep it (it will be used by a later retry or freed with
    /// the cache), and no rows have been written.
    pub fn reserve_append(
        &mut self,
        alloc: &mut dyn FnMut() -> Result<Arc<KvBlock>, KvPressure>,
    ) -> Result<(), KvPressure> {
        for layer in &mut self.layers {
            if layer.tail_full() {
                layer.blocks.push(alloc()?);
            }
        }
        Ok(())
    }
}

impl KvSeqStore for PagedKvCache {
    type Layer = PagedLayer;

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn layer_mut(&mut self, i: usize) -> &mut PagedLayer {
        &mut self.layers[i]
    }

    fn advance(&mut self) {
        self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::LayerKv;

    fn fill_rows(
        pool: &KvBlockPool,
        layer: &mut PagedLayer,
        dense: &mut LayerKv,
        n: usize,
        d: usize,
    ) {
        for i in 0..n {
            let k: Vec<f32> = (0..d).map(|j| (i * d + j) as f32).collect();
            let v: Vec<f32> = (0..d).map(|j| -((i * d + j) as f32)).collect();
            if layer.tail_full() {
                layer.blocks.push(pool.try_alloc().expect("pool has room"));
            }
            layer.push_row(&k, &v);
            dense.push_row(&k, &v);
        }
    }

    #[test]
    fn pool_accounts_alloc_and_drop() {
        let pool = KvBlockPool::new(2, 4, 8);
        assert_eq!(pool.in_use(), 0);
        let a = pool.try_alloc().expect("first alloc fits");
        let b = pool.try_alloc().expect("second alloc fits");
        assert_eq!(pool.in_use(), 2);
        assert_eq!(pool.peak(), 2);
        let err = pool.try_alloc().expect_err("third alloc exceeds budget");
        assert_eq!(
            err,
            KvPressure {
                needed: 1,
                capacity: 2,
                in_use: 2
            }
        );
        drop(a);
        assert_eq!(pool.in_use(), 1);
        let _c = pool.try_alloc().expect("freed slot is reusable");
        assert_eq!(pool.in_use(), 2);
        drop(b);
        drop(_c);
        assert_eq!(pool.in_use(), 0, "all permits returned");
        assert_eq!(pool.peak(), 2, "peak survives frees");
    }

    #[test]
    fn pressure_error_formats_and_boxes() {
        let e = KvPressure {
            needed: 3,
            capacity: 8,
            in_use: 7,
        };
        let msg = e.to_string();
        assert!(msg.contains("need 3"), "unexpected message: {msg}");
        assert!(msg.contains("7/8"), "unexpected message: {msg}");
        let boxed: Box<dyn std::error::Error> = Box::new(e);
        assert!(boxed.to_string().contains("kv pool pressure"));
    }

    #[test]
    fn paged_rows_match_dense_layout() {
        let d = 6;
        let bt = 4;
        let pool = KvBlockPool::new(16, bt, d);
        let mut paged = PagedLayer::new(bt);
        let mut dense = LayerKv::default();
        fill_rows(&pool, &mut paged, &mut dense, 11, d); // spans 3 blocks, partial tail
        assert_eq!(paged.seq_rows(d), 11);
        assert_eq!(paged.blocks.len(), 3);
        for j in 0..11 {
            assert_eq!(paged.k_row(j, d), dense.k_row(j, d), "k row {j}");
            assert_eq!(paged.v_row(j, d), dense.v_row(j, d), "v row {j}");
        }
    }

    #[test]
    fn reserve_append_allocates_per_layer_tails() {
        let d = 4;
        let bt = 2;
        let pool = KvBlockPool::new(8, bt, d);
        let mut cache = PagedKvCache::new(3, bt);
        let mut alloc = || pool.try_alloc();
        cache.reserve_append(&mut alloc).expect("first reserve fits");
        assert_eq!(pool.in_use(), 3, "one tail block per layer");
        for l in 0..3 {
            cache.layer_mut(l).push_row(&vec![0.0; d], &vec![0.0; d]);
        }
        cache.advance();
        // tails have room for a second row: no new blocks needed
        cache.reserve_append(&mut alloc).expect("tails have room");
        assert_eq!(pool.in_use(), 3);
        for l in 0..3 {
            cache.layer_mut(l).push_row(&vec![1.0; d], &vec![1.0; d]);
        }
        cache.advance();
        // tails now full: next reserve takes three more blocks
        cache.reserve_append(&mut alloc).expect("pool still has room");
        assert_eq!(pool.in_use(), 6);
        drop(cache);
        assert_eq!(pool.in_use(), 0, "dropping the cache frees every block");
    }

    #[test]
    fn reserve_append_surfaces_pressure() {
        let bt = 2;
        let pool = KvBlockPool::new(1, bt, 4);
        let mut cache = PagedKvCache::new(2, bt); // needs 2 tails, budget is 1
        let mut alloc = || pool.try_alloc();
        let err = cache.reserve_append(&mut alloc).expect_err("budget too small");
        assert_eq!(err.capacity, 1);
        assert_eq!(pool.in_use(), 1, "layer 0's tail was reserved before the failure");
        drop(cache);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn shared_prefix_blocks_are_copy_on_write() {
        let d = 4;
        let bt = 2;
        let pool = KvBlockPool::new(8, bt, d);
        let mut owner = PagedLayer::new(bt);
        let mut dense = LayerKv::default();
        fill_rows(&pool, &mut owner, &mut dense, 2, d); // exactly one full block

        // a second session adopts the full block and appends its own rows
        let mut twin = PagedLayer::new(bt);
        twin.adopt_prefix(&owner.blocks[..1]);
        assert_eq!(twin.seq_rows(d), 2);
        assert_eq!(pool.in_use(), 1, "adoption shares, not copies");
        twin.blocks.push(pool.try_alloc().expect("room for a tail"));
        twin.push_row(&[9.0; 4], &[9.0; 4]);
        assert_eq!(twin.seq_rows(d), 3);
        // the shared block is untouched and the owner sees its own rows
        for j in 0..2 {
            assert_eq!(owner.k_row(j, d), dense.k_row(j, d));
            assert_eq!(twin.k_row(j, d), dense.k_row(j, d));
        }
        assert_eq!(twin.k_row(2, d), &[9.0; 4]);
        drop(owner);
        assert_eq!(pool.in_use(), 2, "shared block survives its first owner");
        drop(twin);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "uniquely owned")]
    fn writing_a_shared_tail_panics() {
        let bt = 4;
        let pool = KvBlockPool::new(4, bt, 2);
        let mut a = PagedLayer::new(bt);
        a.blocks.push(pool.try_alloc().expect("room"));
        let _alias = Arc::clone(&a.blocks[0]);
        a.push_row(&[0.0; 2], &[0.0; 2]);
    }
}
