"""Layer-1 Pallas kernel: fused low-rank linear  y = x V U^T.

This is the inference hot spot of every SVD-compressed model: each dense
W[m,n] is replaced by U[m,k] V[n,k]^T and the whole point of factorization
(paper §B.3) is that the rank-k intermediate z = V^T x never needs to hit
HBM.

Hardware adaptation: the CUDA version fuses the two GEMMs inside one
threadblock, staging z in shared memory. Here the z tile lives in VMEM
scratch: the grid is (l_tiles, m_tiles) with the m axis fastest; at m==0 we
compute z = x_tile V once per l tile (first MXU pass) and every m step then
consumes the resident scratch for y_tile = z U_tile^T (second MXU pass).
BlockSpec expresses the HBM<->VMEM schedule the paper's GPU kernels express
with threadblock tiling.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .cov import pick_block


def _lowrank_kernel(x_ref, v_ref, u_ref, o_ref, z_ref):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _stage():
        # first GEMM: z = x_tile @ V   (staged in VMEM scratch)
        z_ref[...] = jnp.dot(
            x_ref[...], v_ref[...], preferred_element_type=jnp.float32
        )

    # second GEMM: y_tile = z @ U_tile^T, consuming the resident scratch
    o_ref[...] = jnp.dot(
        z_ref[...], u_ref[...].T, preferred_element_type=jnp.float32
    )


def lowrank_apply(u, v, x, *, block_l: int | None = None,
                  block_m: int | None = None, interpret: bool = True):
    """y = (x @ V) @ U^T.  u: [m, k], v: [n, k], x: [l, n] -> y: [l, m]."""
    m, k = u.shape
    n, k2 = v.shape
    l, n2 = x.shape
    assert k == k2 and n == n2
    bl = block_l or pick_block(l, 128)
    bm = block_m or pick_block(m, 128)
    grid = (l // bl, m // bm)
    return pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, n), lambda i, j: (i, 0)),   # x tile (full n)
            pl.BlockSpec((n, k), lambda i, j: (0, 0)),    # V resident
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),   # U tile
        ],
        out_specs=pl.BlockSpec((bl, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bl, k), jnp.float32)],
        interpret=interpret,
    )(x, v, u)
