//! Serving client-surface lifecycle tests on the artifact-free synthetic
//! backend: streaming order, cancellation (explicit and drop), deadlines,
//! admission control, stop sequences and seeded sampling determinism.
//! These run everywhere — no PJRT artifacts required.

use aasvd::model::Config;
use aasvd::serve::{
    CancelReason, Event, GenParams, ModelBackend, Server, ServerOptions, SubmitError,
    SyntheticBackend, WaitError,
};
use std::time::Duration;

fn synthetic_server(options: ServerOptions, step_delay: Duration) -> Server {
    let cfg = Config::builtin("tiny").unwrap();
    let backend_cfg = cfg.clone();
    Server::with_backend(cfg, options, move || {
        Ok(Box::new(SyntheticBackend::with_delay(backend_cfg, step_delay)) as Box<dyn ModelBackend>)
    })
}

/// Streaming: tokens arrive as individual events, in order, before Done,
/// and the terminal response equals their concatenation.
#[test]
fn streams_tokens_before_done() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let completion = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 4,
                ..Default::default()
            },
        )
        .unwrap();

    let mut streamed = String::new();
    let mut next_index = 0usize;
    let mut last_at = 0.0f64;
    let resp = loop {
        match completion.next_event() {
            Some(Event::Token(t)) => {
                assert_eq!(t.index, next_index, "tokens must stream in order");
                assert!(t.at >= last_at, "event timestamps must be monotone");
                next_index += 1;
                last_at = t.at;
                streamed.push(t.ch);
            }
            Some(Event::Done(resp)) => break resp,
            other => panic!("unexpected event {other:?}"),
        }
    };
    // the first Event::Token was observed before Event::Done
    assert_eq!(next_index, 4);
    assert_eq!(resp.tokens_generated, 4);
    assert_eq!(resp.text, streamed);
    // synthetic backend decodes the successor chain greedily
    assert_eq!(resp.text, "bcde");
    assert!(resp.ttft <= resp.latency);

    let metrics = server.shutdown();
    assert_eq!(metrics.tokens, 4);
    assert_eq!(metrics.cancelled, 0);
}

/// Cancellation: a cancelled request gets a terminal Cancelled event, its
/// slot frees, and later requests still complete.
#[test]
fn cancel_frees_slot_for_later_requests() {
    let server = synthetic_server(
        ServerOptions {
            max_batch: 1,
            ..Default::default()
        },
        Duration::from_millis(5),
    );
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    // wait until decoding has demonstrably started
    match a.next_event() {
        Some(Event::Token(_)) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    a.cancel();
    loop {
        match a.next_event() {
            Some(Event::Token(_)) => continue, // tokens already in flight
            Some(Event::Cancelled { reason, .. }) => {
                assert_eq!(reason, CancelReason::Client);
                break;
            }
            other => panic!("expected cancellation, got {other:?}"),
        }
    }

    // the slot is free again: a fresh request completes
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 3,
                ..Default::default()
            },
        )
        .unwrap();
    let resp = b.wait().expect("post-cancel request must complete");
    assert_eq!(resp.tokens_generated, 3);

    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.deadline_expired, 0);
}

/// Dropping the Completion handle cancels the request.
#[test]
fn dropping_handle_cancels_request() {
    let server = synthetic_server(
        ServerOptions {
            max_batch: 1,
            ..Default::default()
        },
        Duration::from_millis(5),
    );
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                ..Default::default()
            },
        )
        .unwrap();
    drop(a);
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 2,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(b.wait().unwrap().tokens_generated, 2);
    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
}

/// Backpressure: with a bounded queue and a busy decode slot, submit
/// returns Overloaded instead of blocking, and queued work still drains.
#[test]
fn bounded_queue_rejects_with_overloaded() {
    let server = synthetic_server(
        ServerOptions {
            max_queue: 1,
            max_batch: 1,
            poll_interval: Duration::from_millis(1),
        },
        Duration::from_millis(40),
    );
    // occupy the single decode slot with a long request
    let a = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 1000,
                ..Default::default()
            },
        )
        .unwrap();
    match a.next_event() {
        Some(Event::Token(_)) => {} // worker is now decoding `a`
        other => panic!("expected a first token, got {other:?}"),
    }
    // fill the admission queue (the worker cannot drain it: slot is busy)
    let b = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 1,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(server.queue_depth(), 1);
    // queue full -> immediate, non-blocking rejection
    let overloaded = server.submit("c", GenParams::default());
    assert!(matches!(overloaded, Err(SubmitError::Overloaded)));

    // cancel the hog; the queued request is admitted and completes
    drop(a);
    let resp = b.wait().expect("queued request must survive the rejection");
    assert_eq!(resp.tokens_generated, 1);

    let metrics = server.shutdown();
    assert!(metrics.rejected >= 1, "rejections must be counted");
    assert_eq!(metrics.cancelled, 1);
}

/// Deadlines: a request whose budget expires is retired with
/// CancelReason::Deadline and counted separately.
#[test]
fn deadline_expiry_cancels_request() {
    let server = synthetic_server(ServerOptions::default(), Duration::from_millis(15));
    let c = server
        .submit(
            "x",
            GenParams {
                max_new_tokens: 100_000,
                deadline: Some(Duration::from_millis(1)),
                ..Default::default()
            },
        )
        .unwrap();
    match c.wait() {
        Err(WaitError::Cancelled(CancelReason::Deadline)) => {}
        other => panic!("expected deadline cancellation, got {other:?}"),
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.deadline_expired, 1);
}

/// Stop sequences end generation as soon as the generated text ends with
/// any of them.
#[test]
fn stop_sequences_end_generation() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let resp = server
        .submit(
            "a",
            GenParams {
                max_new_tokens: 100,
                stop_sequences: vec!["zz".into(), "de".into()],
                ..Default::default()
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.text, "bcde");
    assert_eq!(resp.tokens_generated, 4);
    server.shutdown();
}

/// A fixed per-request seed makes sampled decoding reproducible even when
/// requests share a continuous batch.
#[test]
fn seeded_sampling_is_deterministic() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let params = GenParams {
        max_new_tokens: 12,
        temperature: 1.0,
        top_k: Some(8),
        seed: Some(42),
        ..Default::default()
    };
    let a = server.submit("hello", params.clone()).unwrap();
    let b = server.submit("hello", params).unwrap();
    let (ra, rb) = (a.wait().unwrap(), b.wait().unwrap());
    assert_eq!(ra.text, rb.text);
    server.shutdown();
}

/// Shutdown drains queued requests rather than dropping them.
#[test]
fn shutdown_drains_queued_requests() {
    let server = synthetic_server(ServerOptions::default(), Duration::ZERO);
    let completions: Vec<_> = (0..8)
        .map(|_| {
            server
                .submit(
                    "a",
                    GenParams {
                        max_new_tokens: 2,
                        ..Default::default()
                    },
                )
                .unwrap()
        })
        .collect();
    let metrics = server.shutdown();
    assert_eq!(metrics.latencies.len(), 8);
    for c in completions {
        assert_eq!(c.wait().unwrap().tokens_generated, 2);
    }
}
