//! Criterion-free benchmarking harness (offline build has no criterion).

pub mod harness;

pub use harness::{Bench, BenchResult};
