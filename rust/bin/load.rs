//! `aasvd-load` — open-loop load generator for the HTTP front door.
//!
//! Open-loop means arrivals follow a precomputed schedule and never wait
//! for responses: a slow server faces a growing backlog exactly like it
//! would in production, instead of the closed-loop mercy of clients that
//! pause while it catches up. Four arrival profiles:
//!
//! - `sustained` — constant rate, evenly spaced
//! - `poisson`   — exponential inter-arrival gaps at the same mean rate
//! - `ramp`      — rate grows linearly from 0 to the peak over the run
//! - `burst`     — the whole second's traffic lands in its first half
//!
//! The whole schedule (arrival times, prompts, seeds) derives from
//! `--seed`, so two runs issue byte-identical requests in the same
//! order. Thousands of sockets are driven from one thread: blocking
//! connect on loopback, then nonblocking writes/reads swept in a tight
//! loop, with chunked-transfer and SSE frames decoded incrementally so
//! TTFT and inter-token latency are stamped when bytes *arrive*, not
//! when a response completes.
//!
//! `--serve synthetic` (the CI `http-smoke` mode) boots the in-process
//! [`HttpServer`] over a [`SyntheticBackend`] with split prefill/step
//! delays, so the whole harness runs artifact-free in one process.
//! `--target host:port` aims at an external server instead.
//!
//! Results land in `--out` (default `results/bench_http.json`):
//! p50/p90/p99 TTFT and inter-token latency, status-class counts, peak
//! concurrency, and the server-side metrics summary when in-process.

use aasvd::model::init::init_params;
use aasvd::model::lowrank::exact_factors;
use aasvd::model::quant_lowrank::QuantBlockFactors;
use aasvd::model::Config;
use aasvd::serve::{
    DecodeMode, DenseBackend, HttpOptions, HttpServer, ModelBackend, PagedKvOptions,
    QuantizedBackend, Server, ServerOptions, SyntheticBackend,
};
use aasvd::util::cli::Args;
use aasvd::util::json::Json;
use aasvd::util::rng::Rng;
use aasvd::util::stats::{mean, percentile};
use anyhow::{anyhow, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args = Args::parse_env(
        "aasvd-load: open-loop HTTP load generator (see README \"HTTP API\")",
    );
    let profile = args.str("profile", "sustained", "arrival profile: sustained|poisson|ramp|burst");
    let rate = args.f64("rate", 100.0, "mean arrival rate, requests/second");
    let duration = args.f64("duration-secs", 5.0, "arrival window length in seconds");
    let max_tokens = args.usize("max-tokens", 100, "tokens requested per completion");
    let seed = args.u64("seed", 7, "schedule + prompt seed (full determinism)");
    let target = args.str("target", "", "external server host:port (empty = --serve)");
    let serve = args.str("serve", "synthetic", "in-process backend when --target is empty");
    let model = args.str("model", "small", "builtin config for the in-process server");
    let step_delay_ms = args.f64("step-delay-ms", 20.0, "synthetic per-decode-tick delay");
    let prefill_delay_ms = args.f64("prefill-delay-ms", 0.0, "synthetic per-prefill delay");
    let max_queue = args.usize("max-queue", 4096, "in-process admission queue bound");
    let max_batch = args.usize("max-batch", 4096, "in-process decode-slot cap");
    let max_connections = args.usize("max-connections", 4096, "in-process HTTP connection cap");
    let shared_prefix = args.usize(
        "shared-prefix",
        0,
        "prepend a shared prefix of this many tokens to prompts (0 = off)",
    );
    let prefix_ratio = args.f64(
        "prefix-ratio",
        1.0,
        "fraction of requests carrying the shared prefix",
    );
    let kv_blocks = args.usize("kv-blocks", 0, "in-process paged KV pool size (0 = dense caches)");
    let kv_block_tokens = args.usize("kv-block-tokens", 16, "tokens per KV block");
    let no_prefix_cache = args.flag("no-prefix-cache", "disable radix prefix sharing when paged");
    let out = args.str("out", "results/bench_http.json", "output JSON path");
    args.finish_or_help();

    // ---- deterministic schedule + request bodies --------------------
    let mut rng = Rng::new(seed);
    let schedule = build_schedule(&profile, rate, duration, &mut rng)?;
    // the shared span is a fixed letter pattern: independent of --seed so
    // two runs with different schedules still collide on the same prefix
    let prefix: String = (0..shared_prefix)
        .map(|j| char::from(b'a' + (j % 26) as u8))
        .collect();
    let mut bodies = Vec::with_capacity(schedule.len());
    for i in 0..schedule.len() {
        let mut fork = rng.fork(i as u64);
        let len = 4 + fork.below(8);
        let tail: String = (0..len)
            .map(|_| char::from(b'a' + fork.below(26) as u8))
            .collect();
        let prompt = if shared_prefix > 0 && fork.f64() < prefix_ratio {
            format!("{prefix}{tail}")
        } else {
            tail
        };
        let body = Json::obj()
            .set("prompt", prompt)
            .set("max_tokens", max_tokens)
            .set("stream", true)
            .set("seed", i as f64)
            .to_string();
        bodies.push(body);
    }

    // ---- target: external, or an in-process synthetic stack ---------
    let mut http = None;
    let paged_kv = (kv_blocks > 0).then(|| PagedKvOptions {
        blocks: kv_blocks,
        block_tokens: kv_block_tokens.max(1),
        prefix_cache: !no_prefix_cache,
    });
    let addr = if target.is_empty() {
        if !matches!(serve.as_str(), "synthetic" | "dense" | "quantized") {
            return Err(anyhow!(
                "--serve supports 'synthetic', 'dense', or 'quantized' (got '{serve}')"
            ));
        }
        if paged_kv.is_some() && serve == "synthetic" {
            return Err(anyhow!(
                "--kv-blocks needs --serve dense or quantized (the synthetic backend has no KV cache to page)"
            ));
        }
        let cfg = Config::builtin(&model)
            .ok_or_else(|| anyhow!("unknown builtin config '{model}'"))?;
        let backend_cfg = cfg.clone();
        let backend_kind = serve.clone();
        let prefill_delay = Duration::from_secs_f64(prefill_delay_ms.max(0.0) / 1e3);
        let step_delay = Duration::from_secs_f64(step_delay_ms.max(0.0) / 1e3);
        let server = Server::with_backend(
            cfg,
            ServerOptions {
                max_queue,
                max_batch,
                decode: DecodeMode::Cached,
                // open-loop load: drain the whole admission queue each
                // tick, or arrival bursts stack up behind one-per-tick
                prefill_per_tick: 0,
                paged_kv: paged_kv.clone(),
                ..Default::default()
            },
            move || -> Result<Box<dyn ModelBackend>> {
                if backend_kind == "dense" {
                    let params = init_params(&backend_cfg, &mut Rng::new(0xa5_5eed));
                    return Ok(Box::new(DenseBackend::new(backend_cfg, params)));
                }
                if backend_kind == "quantized" {
                    let params = init_params(&backend_cfg, &mut Rng::new(0xa5_5eed));
                    let blocks = (0..backend_cfg.n_layers)
                        .map(|i| {
                            let bf = exact_factors(&backend_cfg, &params, i);
                            QuantBlockFactors::from_block(&backend_cfg, &bf)
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    return Ok(Box::new(QuantizedBackend::new(backend_cfg, params, blocks)?));
                }
                Ok(Box::new(SyntheticBackend::with_delays(
                    backend_cfg,
                    prefill_delay,
                    step_delay,
                )))
            },
        );
        let front = HttpServer::start(
            server,
            HttpOptions {
                max_connections,
                ..Default::default()
            },
        )
        .context("start in-process HTTP server")?;
        let addr = front.addr().to_string();
        http = Some(front);
        addr
    } else {
        target.clone()
    };

    // ---- the open-loop sweep ----------------------------------------
    eprintln!(
        "aasvd-load: {} requests, profile={profile} rate={rate}/s duration={duration}s -> {addr}",
        schedule.len()
    );
    let run = drive(&addr, &schedule, &bodies);

    let server_metrics = http.map(|h| h.shutdown());
    let server_summary = server_metrics.as_ref().map(|m| m.summary());

    // ---- report -----------------------------------------------------
    let pct = |xs: &[f64], q: f64| if xs.is_empty() { 0.0 } else { 1e3 * percentile(xs, q) };
    let report = Json::obj()
        .set("bench", "http_load")
        .set("profile", profile.as_str())
        .set("rate", rate)
        .set("duration_secs", duration)
        .set("seed", seed as f64)
        .set("max_tokens", max_tokens)
        .set("requests", schedule.len())
        .set("completed", run.completed)
        .set("failed_transport", run.failed_transport)
        .set(
            "status",
            Json::obj()
                .set("s2xx", run.s2xx)
                .set("s4xx", run.s4xx)
                .set("s5xx", run.s5xx),
        )
        .set("max_concurrent", run.max_concurrent)
        .set("tokens_total", run.tokens_total)
        .set("wall_secs", run.wall_secs)
        .set(
            "ttft_ms",
            Json::obj()
                .set("mean", if run.ttfts.is_empty() { 0.0 } else { 1e3 * mean(&run.ttfts) })
                .set("p50", pct(&run.ttfts, 50.0))
                .set("p90", pct(&run.ttfts, 90.0))
                .set("p99", pct(&run.ttfts, 99.0)),
        )
        .set(
            "itl_ms",
            Json::obj()
                .set("p50", pct(&run.itls, 50.0))
                .set("p99", pct(&run.itls, 99.0)),
        )
        .set("shared_prefix", shared_prefix)
        .set("prefix_ratio", prefix_ratio)
        // paged-KV + prefix-cache effectiveness (in-process server only;
        // zeros when driving an external --target)
        .set(
            "prefix",
            match &server_metrics {
                Some(m) => Json::obj()
                    .set("lookups", m.prefix_lookups)
                    .set("hits", m.prefix_hits)
                    .set("hit_rate", m.prefix_hit_rate())
                    .set("tokens_reused", m.prefix_tokens_reused),
                None => Json::Null,
            },
        )
        .set(
            "kv",
            match &server_metrics {
                Some(m) => Json::obj()
                    .set("blocks_capacity", m.kv_blocks_capacity)
                    .set("peak_blocks", m.kv_peak_blocks)
                    .set("blocks_leaked", m.kv_blocks_leaked)
                    .set("evictions", m.kv_evictions as f64)
                    .set("pressure_rejected", m.kv_pressure_rejected),
                None => Json::Null,
            },
        )
        .set(
            "server_summary",
            server_summary.clone().map(Json::from).unwrap_or(Json::Null),
        );
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(&out, report.to_string_pretty()).with_context(|| format!("write {out}"))?;
    eprintln!(
        "aasvd-load: done — completed={} 2xx={} 4xx={} 5xx={} transport_failures={} \
         max_concurrent={} ttft p50={:.0}ms p99={:.0}ms -> {out}",
        run.completed,
        run.s2xx,
        run.s4xx,
        run.s5xx,
        run.failed_transport,
        run.max_concurrent,
        pct(&run.ttfts, 50.0),
        pct(&run.ttfts, 99.0),
    );
    if let Some(s) = server_summary {
        eprintln!("server: {s}");
    }
    Ok(())
}

/// Arrival offsets (seconds from t0), ascending.
fn build_schedule(profile: &str, rate: f64, duration: f64, rng: &mut Rng) -> Result<Vec<f64>> {
    anyhow::ensure!(rate > 0.0 && duration > 0.0, "rate and duration must be positive");
    let n = (rate * duration).round().max(1.0) as usize;
    let times = match profile {
        "sustained" => (0..n).map(|i| i as f64 / rate).collect(),
        "poisson" => {
            let mut t = 0.0;
            let mut times = Vec::with_capacity(n);
            for _ in 0..n {
                // exponential gap with mean 1/rate; clamp u away from 1
                let u = rng.f64().min(1.0 - 1e-12);
                t += -(1.0 - u).ln() / rate;
                times.push(t);
            }
            times
        }
        "ramp" => {
            // instantaneous rate r(t) = peak * t / duration with peak
            // chosen so the window still carries n arrivals: the i-th
            // arrival solves i = peak * t^2 / (2 * duration)
            let peak = 2.0 * rate;
            (0..n)
                .map(|i| (2.0 * (i as f64 + 1.0) * duration / peak).sqrt())
                .collect()
        }
        "burst" => {
            // each second's quota lands evenly in its first half, then
            // silence — a square-wave arrival pattern
            let per_sec = rate.max(1.0) as usize;
            let mut times = Vec::with_capacity(n);
            'outer: for sec in 0.. {
                for i in 0..per_sec {
                    if times.len() >= n {
                        break 'outer;
                    }
                    times.push(sec as f64 + 0.5 * i as f64 / per_sec as f64);
                }
            }
            times
        }
        other => return Err(anyhow!("unknown profile '{other}'")),
    };
    Ok(times)
}

/// One in-flight socket and its incremental response decoder.
struct Conn {
    stream: TcpStream,
    request: Vec<u8>,
    written: usize,
    /// raw bytes received, head + (possibly chunked) body
    raw: Vec<u8>,
    /// index just past `\r\n\r\n`, once seen
    head_end: Option<usize>,
    status: u16,
    chunked: bool,
    /// decode cursor into `raw` for the chunk parser
    chunk_pos: usize,
    /// decoded body bytes (SSE text, or the JSON error body)
    body: Vec<u8>,
    /// cursor into `body` for SSE event extraction
    sse_pos: usize,
    started: f64,
    ttft: Option<f64>,
    last_token: Option<f64>,
    itls: Vec<f64>,
    tokens: usize,
}

enum Pump {
    Continue,
    Finished,
    TransportFailed,
}

impl Conn {
    fn open(addr: &str, body: &str, started: f64) -> std::io::Result<Conn> {
        // loopback connect is effectively instant; go nonblocking after
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true)?;
        let request = format!(
            "POST /v1/completions HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
            body.len(),
        )
        .into_bytes();
        Ok(Conn {
            stream,
            request,
            written: 0,
            raw: Vec::with_capacity(1024),
            head_end: None,
            status: 0,
            chunked: false,
            chunk_pos: 0,
            body: Vec::new(),
            sse_pos: 0,
            started,
            ttft: None,
            last_token: None,
            itls: Vec::new(),
            tokens: 0,
        })
    }

    /// Advance writes and reads as far as the socket allows right now.
    fn pump(&mut self, now: f64) -> Pump {
        // flush the request
        while self.written < self.request.len() {
            match self.stream.write(&self.request[self.written..]) {
                Ok(0) => return Pump::TransportFailed,
                Ok(n) => self.written += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Pump::TransportFailed,
            }
        }
        // drain the socket
        let mut tmp = [0u8; 4096];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    // server closed: with connection: close this is the
                    // universal terminator
                    self.parse(now);
                    return if self.status != 0 { Pump::Finished } else { Pump::TransportFailed };
                }
                Ok(n) => {
                    self.raw.extend_from_slice(&tmp[..n]);
                    self.parse(now);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Pump::Continue,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Pump::TransportFailed,
            }
        }
    }

    /// Incrementally decode head -> chunks -> SSE events, stamping token
    /// arrival times as they surface.
    fn parse(&mut self, now: f64) {
        if self.head_end.is_none() {
            let Some(pos) = self.raw.windows(4).position(|w| w == b"\r\n\r\n") else {
                return;
            };
            let end = pos + 4;
            self.head_end = Some(end);
            self.chunk_pos = end;
            let head = String::from_utf8_lossy(&self.raw[..end]);
            self.status = head
                .lines()
                .next()
                .and_then(|l| l.split(' ').nth(1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            self.chunked = head
                .to_ascii_lowercase()
                .contains("transfer-encoding: chunked");
        }
        if self.chunked {
            self.decode_chunks();
        } else if let Some(end) = self.head_end {
            // fixed-length (error) body: everything after the head
            self.body = self.raw[end..].to_vec();
        }
        self.extract_sse_events(now);
    }

    /// Peel complete `size\r\n payload \r\n` frames off `raw`.
    fn decode_chunks(&mut self) {
        loop {
            let rest = &self.raw[self.chunk_pos..];
            let Some(line_end) = rest.windows(2).position(|w| w == b"\r\n") else {
                return;
            };
            let size_text = String::from_utf8_lossy(&rest[..line_end]);
            let Ok(size) = usize::from_str_radix(size_text.trim(), 16) else {
                return;
            };
            let frame = line_end + 2 + size + 2;
            if rest.len() < frame {
                return; // incomplete chunk; wait for more bytes
            }
            if size > 0 {
                self.body
                    .extend_from_slice(&rest[line_end + 2..line_end + 2 + size]);
            }
            self.chunk_pos += frame;
            if size == 0 {
                return; // terminal chunk
            }
        }
    }

    /// Count complete `event: ...\ndata: ...\n\n` blocks in `body`.
    fn extract_sse_events(&mut self, now: f64) {
        loop {
            let rest = &self.body[self.sse_pos..];
            let Some(sep) = rest.windows(2).position(|w| w == b"\n\n") else {
                return;
            };
            let block = String::from_utf8_lossy(&rest[..sep]).to_string();
            self.sse_pos += sep + 2;
            if block.lines().any(|l| l.trim() == "event: token") {
                self.tokens += 1;
                let at = now - self.started;
                if self.ttft.is_none() {
                    self.ttft = Some(at);
                }
                if let Some(prev) = self.last_token {
                    self.itls.push(at - prev);
                }
                self.last_token = Some(at);
            }
        }
    }
}

/// Aggregated results of one sweep.
#[derive(Default)]
struct RunStats {
    completed: usize,
    failed_transport: usize,
    s2xx: usize,
    s4xx: usize,
    s5xx: usize,
    max_concurrent: usize,
    tokens_total: usize,
    wall_secs: f64,
    ttfts: Vec<f64>,
    itls: Vec<f64>,
}

impl RunStats {
    fn settle(&mut self, conn: Conn) {
        self.completed += 1;
        match conn.status {
            200..=299 => self.s2xx += 1,
            400..=499 => self.s4xx += 1,
            _ => self.s5xx += 1,
        }
        self.tokens_total += conn.tokens;
        if let Some(t) = conn.ttft {
            self.ttfts.push(t);
        }
        self.itls.extend(conn.itls);
    }
}

/// The single-threaded nonblocking sweep over the whole schedule.
fn drive(addr: &str, schedule: &[f64], bodies: &[String]) -> RunStats {
    let mut stats = RunStats::default();
    let mut conns: Vec<Conn> = Vec::new();
    let mut next = 0;
    let t0 = Instant::now();
    while next < schedule.len() || !conns.is_empty() {
        let now = t0.elapsed().as_secs_f64();
        // launch everything that is due (open-loop: never wait)
        while next < schedule.len() && schedule[next] <= now {
            match Conn::open(addr, &bodies[next], t0.elapsed().as_secs_f64()) {
                Ok(c) => conns.push(c),
                Err(_) => stats.failed_transport += 1,
            }
            next += 1;
        }
        stats.max_concurrent = stats.max_concurrent.max(conns.len());
        // sweep every live socket once
        let now = t0.elapsed().as_secs_f64();
        let mut i = 0;
        while i < conns.len() {
            match conns[i].pump(now) {
                Pump::Continue => i += 1,
                Pump::Finished => stats.settle(conns.swap_remove(i)),
                Pump::TransportFailed => {
                    conns.swap_remove(i);
                    stats.failed_transport += 1;
                }
            }
        }
        // don't spin hot between arrivals
        std::thread::sleep(Duration::from_micros(500));
    }
    stats.wall_secs = t0.elapsed().as_secs_f64();
    stats
}
