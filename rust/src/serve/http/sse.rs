//! Response writing: status lines, JSON error bodies, and chunked
//! server-sent-event (SSE) streams.
//!
//! The streaming endpoint defers its response head until the first
//! event is ready to go out. That keeps the status line honest: a
//! deadline that expires before the first token becomes a real 408 on
//! the wire instead of a half-written 200 (see `server.rs`).

use crate::util::json::Json;
use std::io::{self, Write};

/// Reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

/// Write a complete fixed-length response. Returns bytes written.
pub fn write_response(
    out: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &str,
) -> io::Result<usize> {
    let msg = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
        reason(status),
        body.len(),
    );
    out.write_all(msg.as_bytes())?;
    out.flush()?;
    Ok(msg.len())
}

/// Write a JSON error body `{"error": <reason>, "detail": <detail>}`
/// with the given status. Returns bytes written.
pub fn write_error(out: &mut impl Write, status: u16, detail: &str) -> io::Result<usize> {
    let body = Json::obj()
        .set("error", reason(status))
        .set("detail", detail)
        .to_string();
    write_response(out, status, "application/json", &body)
}

/// A chunked `text/event-stream` response in progress.
///
/// [`SseStream::start`] writes the 200 head; each [`SseStream::event`]
/// goes out as one HTTP chunk holding one SSE event
/// (`event: <name>\n` `data: <json>\n\n`), flushed immediately so
/// time-to-first-token is socket-real. [`SseStream::finish`] writes the
/// zero-length terminal chunk.
pub struct SseStream<'a, W: Write> {
    out: &'a mut W,
    bytes: usize,
    finished: bool,
}

impl<'a, W: Write> SseStream<'a, W> {
    /// Write the streaming response head and return the live stream.
    pub fn start(out: &'a mut W) -> io::Result<Self> {
        let head = "HTTP/1.1 200 OK\r\ncontent-type: text/event-stream\r\ncache-control: no-store\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n";
        out.write_all(head.as_bytes())?;
        out.flush()?;
        Ok(SseStream {
            out,
            bytes: head.len(),
            finished: false,
        })
    }

    /// Emit one SSE event as one chunk and flush it.
    pub fn event(&mut self, name: &str, data: &Json) -> io::Result<()> {
        let payload = format!("event: {name}\ndata: {data}\n\n");
        let chunk = format!("{:x}\r\n{payload}\r\n", payload.len());
        self.out.write_all(chunk.as_bytes())?;
        self.out.flush()?;
        self.bytes += chunk.len();
        Ok(())
    }

    /// Write the terminal zero-length chunk (idempotent).
    pub fn finish(&mut self) -> io::Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        self.out.write_all(b"0\r\n\r\n")?;
        self.out.flush()?;
        self.bytes += 5;
        Ok(())
    }

    /// Total bytes pushed to the socket through this stream, head
    /// included — feeds `ServeMetrics::http_bytes_out`.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn fixed_response_has_length_and_close() {
        let mut out = Vec::new();
        let n = write_response(&mut out, 200, "application/json", "{\"ok\":true}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(n, text.len());
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 11\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
    }

    #[test]
    fn error_bodies_carry_reason_and_detail() {
        let mut out = Vec::new();
        write_error(&mut out, 429, "admission queue full").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("\"error\":\"Too Many Requests\""), "{text}");
        assert!(text.contains("\"detail\":\"admission queue full\""), "{text}");
    }

    #[test]
    fn sse_stream_frames_chunks_and_terminates() {
        let mut out = Vec::new();
        let mut sse = SseStream::start(&mut out).unwrap();
        sse.event("token", &Json::obj().set("text", "a")).unwrap();
        sse.finish().unwrap();
        sse.finish().unwrap(); // idempotent
        let total = sse.bytes();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(total, text.len());
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("transfer-encoding: chunked\r\n"), "{text}");
        // one chunk: hex size, CRLF, payload, CRLF
        let payload = "event: token\ndata: {\"text\":\"a\"}\n\n";
        let framed = format!("{:x}\r\n{payload}\r\n", payload.len());
        assert!(text.contains(&framed), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }
}
