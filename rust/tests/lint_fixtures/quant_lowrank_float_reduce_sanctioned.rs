// aasvd-lint: path=src/model/quant_lowrank.rs

// The fused int8 kernels are a sanctioned banded-kernel file: their
// accumulation order is exactly the f32 kernels' order, which is the
// bitwise fused-vs-dequant contract. No violation.
pub fn fused_dot(x: &[f32], q: &[i8], s: f32) -> f32 {
    x.iter()
        .zip(q)
        .map(|(xv, &qv)| xv * (qv as f32 * s))
        .sum::<f32>()
}
