// aasvd-lint: path=src/serve/fixture.rs

pub fn hot_path(v: &[f32]) -> f32 {
    // aasvd-lint: allow(serve-unwrap): fixture justification — invariant established by the caller, panic preferable
    let first = v.first().unwrap();
    // aasvd-lint: allow(serve-unwrap): fixture justification — same invariant as above
    let last = v.last().expect("nonempty");
    first + last
}
