//! `aasvd-lint`: the repo-specific determinism/robustness static pass.
//!
//! The repo's correctness contract is that every parallel kernel is
//! bitwise thread-count invariant and the serving stack never panics on
//! its hot path. The runtime suites (`tests/parallel_determinism.rs`,
//! `tests/batched_decode.rs`) check this dynamically; this module checks
//! the *source* for the constructs that break it — ad-hoc threads, hash
//! iteration in numeric trees, unsanctioned float reductions,
//! `partial_cmp` NaN traps, hidden env knobs, wall-clock reads in
//! compute paths, and `unwrap` in `src/serve/`.
//!
//! Run it with `cargo run --bin aasvd-lint -- rust/` (or any set of
//! roots); add `--json` for machine-readable output. Suppression
//! syntax and the policy table are documented in [`scan`] and
//! [`rules`], and in README "Correctness tooling".

pub mod report;
pub mod rules;
pub mod scan;

pub use report::{render_human, render_json, sort_violations};
pub use rules::{applies, policy_path, RuleDef, RULES};
pub use scan::{scan_file, scan_source, scan_tree, Violation};
