// aasvd-lint: path=src/serve/fixture.rs

// Mentions of std::thread::spawn, HashMap, Instant::now and env::var in
// line comments must not fire.
/* Neither in block comments: .unwrap() partial_cmp SystemTime
   /* nested blocks too: .expect( .sum::<f32> rayon */ still inside */
pub fn describe() -> &'static str {
    let _raw = r#"thread::spawn in a raw "quoted" string"#;
    let _ch = '"';
    "patterns in strings are fine: .unwrap() .expect( env::var partial_cmp"
}
