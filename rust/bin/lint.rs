//! `aasvd-lint` — the repo's determinism/robustness static pass.
//!
//! Usage: `aasvd-lint [--json] [ROOT ...]`
//!
//! Scans every `.rs` file under the given roots (default: the current
//! directory), skipping `target/` and the known-bad fixture corpus
//! `tests/lint_fixtures/` — unless a fixture path is passed explicitly
//! as a root, which is how CI proves the corpus still fails.
//!
//! Exit codes: 0 = clean, 1 = violations found, 2 = usage or I/O error.

use std::path::Path;
use std::process::ExitCode;

use aasvd::lint::{render_human, render_json, scan_tree, sort_violations, Violation};

const USAGE: &str = "usage: aasvd-lint [--json] [ROOT ...]\n\
                     scans .rs files under each ROOT (default: .) for \
                     determinism-rule violations";

fn main() -> ExitCode {
    let mut json = false;
    let mut roots: Vec<String> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("aasvd-lint: unknown flag '{other}'\n{USAGE}");
                return ExitCode::from(2);
            }
            other => roots.push(other.to_string()),
        }
    }
    if roots.is_empty() {
        roots.push(".".to_string());
    }

    let mut violations: Vec<Violation> = Vec::new();
    let mut files_scanned = 0usize;
    for root in &roots {
        match scan_tree(Path::new(root)) {
            Ok((files, found)) => {
                files_scanned += files;
                violations.extend(found);
            }
            Err(e) => {
                eprintln!("aasvd-lint: {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    sort_violations(&mut violations);

    if json {
        println!("{}", render_json(&violations, files_scanned).to_string_pretty());
    } else {
        print!("{}", render_human(&violations, files_scanned));
    }
    if violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
