//! Resume-equivalence tests for the checkpointed compression pipeline:
//! a run interrupted after any block and resumed — at the same or a
//! different thread count — must produce an artifact and a run manifest
//! bitwise identical to an uninterrupted run's. Also pins the refusal
//! paths: stale directories, tampered shards, changed inputs, future
//! manifest versions.
//!
//! Interrupts are simulated by dropping a `CompressRun` mid-loop without
//! calling `finish()`: `CompressRun` has no Drop logic, so the run
//! directory is left in exactly the state a kill -9 after the last
//! commit would leave it in. (The CLI-level `--crash-after-block` smoke
//! in CI covers the literal process-abort path.)

use std::path::{Path, PathBuf};

use aasvd::compress::{
    compress_model, CompressRun, CompressSummary, Method, Objective, ReferenceCollector,
    RunOptions,
};
use aasvd::data::{Batcher, Corpus, Domain, TokenBatch};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::load_blocks;
use aasvd::model::{Config, FlatStore};
use aasvd::runtime::{BlockEntry, RunManifest};
use aasvd::util::hash::fnv1a64;
use aasvd::util::rng::Rng;

const RATIO: f64 = 0.6;

/// Everything a run borrows, bundled so helpers can hand out
/// `CompressRun`s tied to one lifetime.
struct Env {
    cfg: Config,
    params: FlatStore,
    calib: Vec<TokenBatch>,
}

/// Small but deep enough for interesting interrupt points: 4 layers,
/// 2 full calibration batches.
fn env() -> Env {
    let cfg = Config {
        name: "resume_synth".into(),
        vocab: 128,
        d_model: 48,
        n_heads: 2,
        n_layers: 4,
        d_ff: 96,
        rope_theta: 10000.0,
        batch: 2,
        seq: 24,
        refine_batch: 4,
        train_batch: 4,
    };
    let params = init_params(&cfg, &mut Rng::new(11));
    let corpus = Corpus::generate(Domain::Wiki, 20_000, 11);
    let calib: Vec<_> = Batcher::new(cfg.batch, cfg.seq)
        .sequential(&corpus.train, 2)
        .into_iter()
        .filter(|b| b.real_rows == cfg.batch)
        .collect();
    assert!(calib.len() >= 2, "synthetic calibration set too small");
    Env { cfg, params, calib }
}

/// Constant name across thread counts: the method name feeds the run
/// fingerprint, and cross-thread resume must hash identically.
fn anchored(threads: usize) -> Method {
    Method::builder("anchored")
        .objective(Objective::Anchored)
        .threads(threads)
        .build()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("aasvd-resume-tests/{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn options(dir: &Path, resume: bool) -> RunOptions {
    let opts = RunOptions::checkpointed(dir);
    if resume {
        opts.resume()
    } else {
        opts
    }
}

/// Drive a checkpointed run to completion and return its summary.
fn run_all(env: &Env, m: &Method, dir: &Path, resume: bool) -> CompressSummary {
    let mut run = CompressRun::new(
        &ReferenceCollector,
        &env.cfg,
        &env.params,
        &env.calib,
        m,
        RATIO,
        options(dir, resume),
    )
    .unwrap();
    while run.next_block().unwrap().is_some() {}
    run.finish().unwrap()
}

/// Solve exactly `blocks` blocks, then drop the run without `finish()` —
/// the on-disk state of a crash right after block `blocks - 1` committed.
fn run_partial(env: &Env, m: &Method, dir: &Path, blocks: usize) {
    let mut run = CompressRun::new(
        &ReferenceCollector,
        &env.cfg,
        &env.params,
        &env.calib,
        m,
        RATIO,
        options(dir, false),
    )
    .unwrap();
    for _ in 0..blocks {
        run.next_block().unwrap().unwrap();
    }
}

fn artifact_bytes(dir: &Path) -> Vec<u8> {
    std::fs::read(dir.join("model.aat")).unwrap()
}

fn manifest_text(dir: &Path) -> String {
    std::fs::read_to_string(dir.join("run.json")).unwrap()
}

#[test]
fn streaming_run_completes_with_counts_and_verified_artifact() {
    let env = env();
    let m = anchored(2);
    let dir = fresh_dir("complete");
    let summary = run_all(&env, &m, &dir, false);

    assert_eq!(summary.total, env.cfg.n_layers);
    assert_eq!(summary.solved, env.cfg.n_layers);
    assert_eq!(summary.resumed, 0);
    assert_eq!(summary.skipped, 0);

    let bytes = artifact_bytes(&dir);
    assert_eq!(summary.artifact_hash, Some(fnv1a64(&bytes)));
    assert_eq!(summary.artifact.as_deref(), Some(dir.join("model.aat")).as_deref());

    let manifest = RunManifest::load(dir.join("run.json")).unwrap();
    assert!(manifest.complete);
    assert_eq!(manifest.first_unwritten(), None);
    assert_eq!(manifest.artifact_hash, summary.artifact_hash);

    // stream snapshots are pure resume state — swept once the artifact
    // is durable
    for b in 1..env.cfg.n_layers {
        assert!(
            !dir.join(format!("state_{b}.aat")).exists(),
            "state_{b}.aat survived finish()"
        );
    }
}

#[test]
fn streamed_artifact_matches_the_in_memory_wrapper() {
    let env = env();
    let m = anchored(2);
    let dir = fresh_dir("vs-inmem");
    run_all(&env, &m, &dir, false);

    let streamed = load_blocks(&env.cfg, dir.join("model.aat")).unwrap();
    let inmem = compress_model(
        &ReferenceCollector,
        &env.cfg,
        &env.params,
        &env.calib,
        &m,
        RATIO,
    )
    .unwrap();
    assert_eq!(streamed.len(), inmem.blocks.len());
    for (a, b) in streamed.iter().zip(&inmem.blocks) {
        assert_eq!(a.factors.data, b.factors.data, "factors diverged");
        assert_eq!(a.masks.data, b.masks.data, "masks diverged");
    }
}

#[test]
fn resume_is_bitwise_identical_at_every_interrupt_point_and_thread_count() {
    let env = env();
    let dir_ref = fresh_dir("equiv-ref");
    run_all(&env, &anchored(1), &dir_ref, false);
    let want_artifact = artifact_bytes(&dir_ref);
    let want_manifest = manifest_text(&dir_ref);

    // (interrupt threads, resume threads): same-width resume plus both
    // cross-width directions — the fingerprint excludes the thread count
    // precisely so these are legal
    for (t_int, t_res) in [(1usize, 1usize), (1, 4), (4, 1)] {
        for k in 1..env.cfg.n_layers {
            let dir = fresh_dir(&format!("equiv-{t_int}-{t_res}-{k}"));
            run_partial(&env, &anchored(t_int), &dir, k);
            assert!(
                !dir.join("model.aat").exists(),
                "interrupted run must not leave a final artifact"
            );

            let mut run = CompressRun::new(
                &ReferenceCollector,
                &env.cfg,
                &env.params,
                &env.calib,
                &anchored(t_res),
                RATIO,
                options(&dir, true),
            )
            .unwrap();
            assert_eq!(run.resumed_blocks(), k, "resume point after {k} blocks");
            while run.next_block().unwrap().is_some() {}
            let summary = run.finish().unwrap();
            assert_eq!(summary.resumed, k);
            assert_eq!(summary.solved, env.cfg.n_layers - k);

            assert_eq!(
                artifact_bytes(&dir),
                want_artifact,
                "artifact diverged: interrupt after {k} at t={t_int}, resume at t={t_res}"
            );
            assert_eq!(
                manifest_text(&dir),
                want_manifest,
                "manifest diverged: interrupt after {k} at t={t_int}, resume at t={t_res}"
            );
        }
    }
}

#[test]
fn resume_under_quantization_is_bitwise() {
    // quantized methods carry the shifted stream (X') through the state
    // snapshots — the round-trip must preserve it bit-exactly too
    let env = env();
    let quantized = |threads: usize| {
        Method::builder("anchored_q")
            .objective(Objective::Anchored)
            .quant()
            .threads(threads)
            .build()
    };
    let dir_ref = fresh_dir("quant-ref");
    run_all(&env, &quantized(2), &dir_ref, false);

    let dir = fresh_dir("quant-resume");
    run_partial(&env, &quantized(2), &dir, 2);
    let summary = run_all(&env, &quantized(2), &dir, true);
    assert_eq!(summary.resumed, 2);
    assert_eq!(summary.solved, env.cfg.n_layers - 2);
    assert_eq!(artifact_bytes(&dir), artifact_bytes(&dir_ref));
    assert_eq!(manifest_text(&dir), manifest_text(&dir_ref));
}

#[test]
fn resuming_a_complete_run_skips_every_block() {
    let env = env();
    let m = anchored(2);
    let dir = fresh_dir("skip-complete");
    let first = run_all(&env, &m, &dir, false);
    let bytes = artifact_bytes(&dir);

    let mut run = CompressRun::new(
        &ReferenceCollector,
        &env.cfg,
        &env.params,
        &env.calib,
        &m,
        RATIO,
        options(&dir, true),
    )
    .unwrap();
    assert_eq!(run.skipped_blocks(), env.cfg.n_layers);
    assert!(run.next_block().unwrap().is_none(), "nothing left to solve");
    let summary = run.finish().unwrap();
    assert_eq!(summary.solved, 0);
    assert_eq!(summary.skipped, env.cfg.n_layers);
    assert_eq!(summary.artifact_hash, first.artifact_hash);
    assert_eq!(artifact_bytes(&dir), bytes, "re-open must not rewrite the artifact");
}

#[test]
fn resume_treats_a_solved_marker_as_unwritten() {
    // a crash between the `solved` marker and the shard write leaves a
    // solved-but-shardless entry; resume must re-solve that block
    let env = env();
    let m = anchored(2);
    let dir_ref = fresh_dir("solved-ref");
    run_all(&env, &m, &dir_ref, false);

    let dir = fresh_dir("solved-marker");
    run_partial(&env, &m, &dir, 2);
    let mut manifest = RunManifest::load(dir.join("run.json")).unwrap();
    manifest.blocks[2] = BlockEntry::solved();
    manifest.save(dir.join("run.json")).unwrap();

    let summary = run_all(&env, &m, &dir, true);
    assert_eq!(summary.resumed, 2, "solved entry must not count as durable");
    assert_eq!(summary.solved, env.cfg.n_layers - 2);
    assert_eq!(artifact_bytes(&dir), artifact_bytes(&dir_ref));
}

#[test]
fn fresh_run_refuses_an_existing_directory() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-existing");
    run_partial(&env, &m, &dir, 1);
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            RATIO,
            options(&dir, false),
        )
        .unwrap_err()
    );
    assert!(err.contains("resume"), "{err}");
}

#[test]
fn resume_refuses_an_empty_directory() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-empty");
    std::fs::create_dir_all(&dir).unwrap();
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            RATIO,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("no run manifest"), "{err}");
}

#[test]
fn resume_refuses_a_changed_ratio() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-ratio");
    run_partial(&env, &m, &dir, 1);
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            0.5,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("fresh run directory"), "{err}");
}

#[test]
fn resume_refuses_changed_weights() {
    // same config/method/ratio identity, different weight bits: only the
    // input fingerprint can catch this
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-weights");
    run_partial(&env, &m, &dir, 1);
    let mut tweaked = env.params.clone();
    tweaked.data[0] += 1.0;
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &tweaked,
            &env.calib,
            &m,
            RATIO,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("fingerprint"), "{err}");
}

#[test]
fn resume_refuses_a_tampered_shard() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-tamper");
    run_partial(&env, &m, &dir, 2);
    let shard = dir.join("block_0.aat");
    let mut bytes = std::fs::read(&shard).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    std::fs::write(&shard, &bytes).unwrap();
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            RATIO,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("does not match"), "{err}");
    assert!(err.contains("block_0.aat"), "{err}");
}

#[test]
fn resume_refuses_a_future_manifest_version() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-version");
    run_partial(&env, &m, &dir, 1);
    let path = dir.join("run.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let bumped = text.replacen("\"version\": 1", "\"version\": 99", 1);
    assert_ne!(bumped, text, "version field not found in run.json");
    std::fs::write(&path, bumped).unwrap();
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            RATIO,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("version"), "{err}");
}

#[test]
fn resume_refuses_a_truncated_manifest() {
    let env = env();
    let m = anchored(1);
    let dir = fresh_dir("refuse-truncated");
    run_partial(&env, &m, &dir, 1);
    let path = dir.join("run.json");
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let err = format!(
        "{:#}",
        CompressRun::new(
            &ReferenceCollector,
            &env.cfg,
            &env.params,
            &env.calib,
            &m,
            RATIO,
            options(&dir, true),
        )
        .unwrap_err()
    );
    assert!(err.contains("run.json"), "{err}");
    assert!(err.contains("byte"), "{err}");
}
