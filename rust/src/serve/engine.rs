//! Serving engine: a worker thread owning a [`ModelBackend`] runs a
//! continuous-batching decode loop; clients submit prompts through a
//! bounded admission queue and observe each request through a streaming,
//! cancellable [`Completion`] handle.
//!
//! Decode strategy: KV-cached batched decode. Admission runs one prefill
//! pass over the request's prompt (building its [`Session`] KV cache and
//! the first logits row); every decode iteration then samples one token
//! per active request and advances *all* still-running sessions with a
//! single `decode_batch` call — one stacked [B, d] forward per tick,
//! amortizing weight reads and engine overhead across the batch, instead
//! of B independent batch-1 passes — admitting/retiring requests between
//! iterations (vLLM-style continuous batching at sequence granularity;
//! the batch never drains to refill, and retiring a slot drops its
//! cache). A per-row backend failure retires only that request
//! (`CancelReason::Backend`); every surviving row is bitwise identical
//! to its per-session `decode_step` result. The pre-cache full-prefix
//! recompute path survives as [`DecodeMode::Recompute`]: the engine's
//! test oracle and bench baseline, guaranteed bitwise token-identical to
//! the cached path.
//!
//! Request lifecycle:
//!   submit → (queued) → admitted → Token* → Done
//!                     ↘ Overloaded (queue full, never blocks)
//!            any point ↘ Cancelled (client cancel / dropped handle /
//!                                   deadline) — the slot is retired at the
//!                                   next decode iteration

use super::backend::{ModelBackend, ServedModel, Session};
use super::kv_pool::PagedKvOptions;
use super::metrics::ServeMetrics;
use super::request::{
    CancelReason, Event, GenParams, GenRequest, GenResponse, SubmitError, TokenEvent,
};
use crate::model::paged_kv::KvPressure;
use crate::model::Config;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How the decode loop turns a request's prefix into logits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DecodeMode {
    /// Prefill once, then one stacked KV-cached `decode_batch` per tick
    /// (O(len) attention per token, all active sessions in one [B, d]
    /// forward). The production path.
    #[default]
    Cached,
    /// Re-run the full prefix through `oracle_logits` for every token
    /// (the pre-KV-cache path, O(len²) attention per step). Kept as the
    /// bitwise test oracle and the bench baseline.
    Recompute,
}

/// Server tuning knobs (admission control + batching + decode path).
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Admission-queue capacity (clamped to ≥ 1). `submit` returns
    /// `Err(SubmitError::Overloaded)` instead of blocking when full.
    pub max_queue: usize,
    /// Max concurrent decode slots; 0 = `cfg.batch`. Explicit values are
    /// honored as-is (the pure-Rust decode path has no fixed batch shape).
    pub max_batch: usize,
    /// How long the worker blocks waiting for a request when idle.
    pub poll_interval: Duration,
    /// Cached (default) vs full-prefix-recompute decoding; both produce
    /// bitwise-identical tokens (the cache-exactness contract).
    pub decode: DecodeMode,
    /// Hard cap on a request's total context (prompt + generated tokens):
    /// longer prompts are clamped to their most recent `max_context`
    /// tokens at admission (the old decode window's semantics, bounding
    /// the prefill cost and the KV allocation itself), and a request
    /// whose context reaches the cap completes with what it has. Bounds
    /// per-request KV residency at n_layers × 2 × d_model × 4 bytes per
    /// token and per-step attention cost. 0 = unlimited. Depends only on
    /// token count, so cached and recompute modes cap identically.
    pub max_context: usize,
    /// Prefill attempts per decode iteration. 1 (the default) keeps the
    /// historical behavior — a burst of queued long prompts interleaves
    /// with decode steps instead of stalling token emission for every
    /// active session. Raise it (or set 0 = drain the whole queue each
    /// iteration) when prefill is cheap relative to a decode tick — e.g.
    /// the HTTP front door under open-loop load against a synthetic
    /// backend, where admitting one request per ~tick would cap the
    /// admission rate far below the arrival rate.
    pub prefill_per_tick: usize,
    /// Paged KV memory: `Some` asks the backend to store sessions in a
    /// bounded pool of fixed-size KV blocks (with an optional radix
    /// prefix cache), and makes admission **memory-aware** — a request
    /// whose projected block footprint (clamped prompt + full token
    /// budget, per layer) can never fit the pool is rejected with
    /// `CancelReason::KvPressure` (HTTP 429), and one that merely does
    /// not fit *right now* waits in the queue until committed blocks
    /// free up. Backends that do not support paging (e.g. synthetic)
    /// decline and the engine falls back to dense per-session caches.
    pub paged_kv: Option<PagedKvOptions>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            max_queue: 64,
            max_batch: 0,
            poll_interval: Duration::from_millis(20),
            decode: DecodeMode::Cached,
            max_context: 0,
            prefill_per_tick: 1,
            paged_kv: None,
        }
    }
}

/// Admission state shared between client handles and the worker. The
/// queue bound is enforced on `queue_depth` (submitted but not yet
/// seated in a decode slot), not on the channel, so the worker can pull
/// queued requests into its own deque and deadline-sweep them while all
/// slots are busy.
struct Shared {
    queue_depth: AtomicUsize,
    rejected: AtomicUsize,
    max_queue: usize,
}

/// A streaming, cancellable handle to one submitted request.
///
/// Events arrive in order: zero or more `Event::Token`, then exactly one
/// terminal `Event::Done` or `Event::Cancelled`. Dropping the handle
/// cancels the request; its decode slot is retired at the next iteration.
pub struct Completion {
    id: u64,
    events: Receiver<Event>,
    cancelled: Arc<AtomicBool>,
    /// a terminal event has been consumed through this handle
    finished: Cell<bool>,
}

/// Why `Completion::wait` did not return a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaitError {
    /// the request was retired before completing
    Cancelled(CancelReason),
    /// the server went away without sending a terminal event
    Disconnected,
    /// `wait_timeout` gave up before a terminal event arrived
    TimedOut,
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Cancelled(r) => write!(f, "request {r}"),
            WaitError::Disconnected => write!(f, "server disconnected mid-request"),
            WaitError::TimedOut => write!(f, "timed out waiting for the request"),
        }
    }
}

impl std::error::Error for WaitError {}

impl Completion {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the server to retire this request; the slot frees at the next
    /// decode iteration and a terminal `Event::Cancelled` is delivered.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    fn note(&self, event: &Event) {
        if matches!(event, Event::Done(_) | Event::Cancelled { .. }) {
            self.finished.set(true);
        }
    }

    /// Blocking: the next lifecycle event, or None once the terminal event
    /// has been consumed (or the server is gone).
    pub fn next_event(&self) -> Option<Event> {
        let event = self.events.recv().ok()?;
        self.note(&event);
        Some(event)
    }

    /// Non-blocking variant of `next_event`: `Ok(None)` means no event is
    /// ready *yet* (or the stream already ended normally);
    /// `Err(Disconnected)` means the server died without a terminal event,
    /// so polling again is pointless.
    pub fn try_next_event(&self) -> Result<Option<Event>, WaitError> {
        match self.events.try_recv() {
            Ok(event) => {
                self.note(&event);
                Ok(Some(event))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) if self.finished.get() => Ok(None),
            Err(TryRecvError::Disconnected) => Err(WaitError::Disconnected),
        }
    }

    /// Drain events until the terminal one; discards intermediate tokens
    /// (they are all present in `GenResponse::text`).
    pub fn wait(self) -> Result<GenResponse, WaitError> {
        loop {
            match self.events.recv() {
                Ok(Event::Token(_)) => continue,
                Ok(Event::Done(resp)) => return Ok(resp),
                Ok(Event::Cancelled { reason, .. }) => return Err(WaitError::Cancelled(reason)),
                Err(_) => return Err(WaitError::Disconnected),
            }
        }
    }

    /// `wait` bounded by an overall timeout (the request is *not* cancelled
    /// on timeout — drop or `.cancel()` the handle for that).
    pub fn wait_timeout(self, timeout: Duration) -> Result<GenResponse, WaitError> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.events.recv_timeout(remaining) {
                Ok(Event::Token(_)) => continue,
                Ok(Event::Done(resp)) => return Ok(resp),
                Ok(Event::Cancelled { reason, .. }) => return Err(WaitError::Cancelled(reason)),
                Err(RecvTimeoutError::Timeout) => return Err(WaitError::TimedOut),
                Err(RecvTimeoutError::Disconnected) => return Err(WaitError::Disconnected),
            }
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        // dropping the handle cancels the request (no-op if already done)
        self.cancelled.store(true, Ordering::Relaxed);
    }
}

pub struct Server {
    tx: Option<Sender<GenRequest>>,
    next_id: Arc<AtomicU64>,
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<ServeMetrics>>,
}

/// A cloneable, `Send` submission handle detached from the [`Server`]'s
/// lifetime — the HTTP front door hands one to every connection thread
/// so requests can be submitted without sharing the server itself.
///
/// All handles draw ids from the server's counter and count against the
/// same bounded admission queue. A live `Submitter` keeps the worker's
/// request channel open, so `Server::shutdown` only drains once every
/// clone has been dropped (connection threads drop theirs on exit);
/// submitting after the worker exited reports `SubmitError::ShutDown`.
#[derive(Clone)]
pub struct Submitter {
    tx: Sender<GenRequest>,
    shared: Arc<Shared>,
    next_id: Arc<AtomicU64>,
}

impl Submitter {
    /// Same contract as [`Server::submit`]: a streaming `Completion`, or
    /// `Err(Overloaded)` immediately when the admission queue is full.
    pub fn submit(&self, prompt: &str, params: GenParams) -> Result<Completion, SubmitError> {
        do_submit(&self.tx, &self.shared, &self.next_id, prompt, params)
    }

    /// Requests submitted but not yet seated in a decode slot.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth.load(Ordering::Relaxed)
    }
}

/// The shared submit path behind [`Server::submit`] and
/// [`Submitter::submit`]: reserve a queue slot, build the request, hand
/// back the streaming handle.
fn do_submit(
    tx: &Sender<GenRequest>,
    shared: &Shared,
    next_id: &AtomicU64,
    prompt: &str,
    params: GenParams,
) -> Result<Completion, SubmitError> {
    // reserve a queue slot atomically (the bound lives on the counter,
    // not the channel); the worker releases it when the request seats
    // in a decode slot or is retired while queued
    let reserved = shared
        .queue_depth
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |depth| {
            (depth < shared.max_queue).then_some(depth + 1)
        })
        .is_ok();
    if !reserved {
        shared.rejected.fetch_add(1, Ordering::Relaxed);
        return Err(SubmitError::Overloaded);
    }
    let (event_tx, event_rx) = channel();
    let cancelled = Arc::new(AtomicBool::new(false));
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let req = GenRequest {
        id,
        prompt: prompt.to_string(),
        params,
        submitted: Instant::now(),
        events: event_tx,
        cancelled: cancelled.clone(),
    };
    match tx.send(req) {
        Ok(()) => Ok(Completion {
            id,
            events: event_rx,
            cancelled,
            finished: Cell::new(false),
        }),
        Err(_) => {
            // saturating release: a dying worker zeroes the counter, and
            // losing the race to it must not wrap the depth to usize::MAX
            let _ = shared.queue_depth.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |depth| depth.checked_sub(1),
            );
            Err(SubmitError::ShutDown)
        }
    }
}

impl Server {
    /// Start a server over a built-in model kind with default options.
    /// The backend decodes through the KV-cached pure-Rust forward — no
    /// artifact directory required.
    pub fn start(cfg: Config, model: ServedModel) -> Server {
        Server::start_with(cfg, model, ServerOptions::default())
    }

    /// `start` with explicit admission/batching/decode options.
    pub fn start_with(cfg: Config, model: ServedModel, options: ServerOptions) -> Server {
        let backend_cfg = cfg.clone();
        Server::with_backend(cfg, options, move || model.into_backend(&backend_cfg))
    }

    /// Start a server over any [`ModelBackend`]. The factory runs on the
    /// worker thread, so the backend itself does not need to be `Send`.
    pub fn with_backend<F>(cfg: Config, options: ServerOptions, make_backend: F) -> Server
    where
        F: FnOnce() -> Result<Box<dyn ModelBackend>> + Send + 'static,
    {
        let (tx, rx) = channel::<GenRequest>();
        let shared = Arc::new(Shared {
            queue_depth: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            max_queue: options.max_queue.max(1),
        });
        let worker_shared = shared.clone();
        #[allow(clippy::expect_used)]
        // aasvd-lint: allow(adhoc-parallelism): the one sanctioned long-lived thread — Pool is for scoped fan-out, not a persistent decode loop owning non-Send backend state
        let worker = std::thread::Builder::new()
            .name("aasvd-serve".into())
            .spawn(move || {
                // on failure: keep the metrics recorded so far and exit,
                // dropping rx so later submits see ShutDown and pending
                // completions see Disconnected — no panic cascading into
                // shutdown()'s join
                let mut metrics = ServeMetrics::default();
                match make_backend() {
                    Ok(mut backend) => {
                        if let Err(e) = decode_loop(
                            &cfg,
                            &options,
                            backend.as_mut(),
                            &rx,
                            &worker_shared,
                            &mut metrics,
                        ) {
                            crate::log_warn!("serve decode loop failed: {e:#}");
                        }
                    }
                    Err(e) => crate::log_warn!("serve backend init failed: {e:#}"),
                }
                // release reservations of requests this worker will never
                // seat, so a dead server reports ShutDown, not Overloaded
                worker_shared.queue_depth.store(0, Ordering::Relaxed);
                metrics.rejected = worker_shared.rejected.load(Ordering::Relaxed);
                metrics
            })
            // aasvd-lint: allow(serve-unwrap): OS thread-spawn failure at startup has no request to retire; aborting construction is the only sane outcome
            .expect("spawn serve worker");
        Server {
            tx: Some(tx),
            next_id: Arc::new(AtomicU64::new(1)),
            shared,
            worker: Some(worker),
        }
    }

    /// Submit a prompt. Returns a streaming `Completion` handle, or
    /// `Err(Overloaded)` immediately when the admission queue is full —
    /// submission never blocks on the decode loop.
    pub fn submit(&self, prompt: &str, params: GenParams) -> Result<Completion, SubmitError> {
        let tx = self.tx.as_ref().ok_or(SubmitError::ShutDown)?;
        do_submit(tx, &self.shared, &self.next_id, prompt, params)
    }

    /// A detached, cloneable submission handle (see [`Submitter`]).
    /// Returns `Err(ShutDown)` once the server has begun shutting down.
    pub fn submitter(&self) -> Result<Submitter, SubmitError> {
        let tx = self.tx.as_ref().ok_or(SubmitError::ShutDown)?.clone();
        Ok(Submitter {
            tx,
            shared: self.shared.clone(),
            next_id: self.next_id.clone(),
        })
    }

    /// Requests submitted but not yet seated in a decode slot.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth.load(Ordering::Relaxed)
    }

    /// Close the queue, drain queued + in-flight requests, collect final
    /// metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.tx.take(); // disconnect: worker drains and exits
        match self.worker.take() {
            Some(worker) => match worker.join() {
                Ok(metrics) => metrics,
                // re-raise the worker's panic on the caller's thread with
                // its original payload
                Err(panic) => std::panic::resume_unwind(panic),
            },
            None => ServeMetrics::default(),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take(); // must disconnect BEFORE joining or the worker spins
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

struct Slot {
    req: GenRequest,
    rng: Rng,
    tokens: Vec<i32>,
    prompt_len: usize,
    /// generated text so far (byte tokens widened to chars)
    gen_text: String,
    ttft: Option<f64>,
    /// KV-cache session (None in `DecodeMode::Recompute`); dropped with
    /// the slot when the request retires, freeing the cache
    session: Option<Session>,
    /// logits row ([vocab]) the next token is sampled from — seeded by
    /// prefill at admission, refreshed by each decode step
    next_logits: Vec<f32>,
    /// KV blocks this request was admitted against (0 when the backend
    /// is not paged); released back to the committed budget on retire
    kv_projection: usize,
}

fn new_slot(req: GenRequest) -> Slot {
    let tokens: Vec<i32> = req.prompt.bytes().map(|x| x as i32).collect();
    let tokens = if tokens.is_empty() {
        vec![b' ' as i32]
    } else {
        tokens
    };
    let seed = req.params.seed.unwrap_or(0xd00d_5eed ^ req.id);
    Slot {
        prompt_len: tokens.len(),
        tokens,
        rng: Rng::new(seed),
        gen_text: String::new(),
        req,
        ttft: None,
        session: None,
        next_logits: Vec::new(),
        kv_projection: 0,
    }
}

/// Worst-case KV block footprint of a request on a paged backend: one
/// block chain per layer covering the clamped prompt plus the full
/// generation budget. An upper bound — prefix-cache hits *share* blocks
/// rather than allocating fresh ones — so admitting only while the sum
/// of projections fits the pool guarantees every block reservation made
/// on behalf of an admitted request succeeds (trie-only blocks are
/// evictable on demand and sharing only lowers physical residency).
fn kv_block_projection(
    req: &GenRequest,
    options: &ServerOptions,
    cfg: &Config,
    pk: &PagedKvOptions,
) -> usize {
    let mut plen = req.prompt.len().max(1); // empty prompts decode from " "
    if options.max_context > 0 {
        plen = plen.min(options.max_context);
    }
    let mut ctx = plen + req.params.max_new_tokens;
    if options.max_context > 0 {
        ctx = ctx.min(options.max_context);
    }
    cfg.n_layers * ctx.div_ceil(pk.block_tokens.max(1))
}

/// The reason a live request should be retired early, if any.
fn cancel_reason(req: &GenRequest) -> Option<CancelReason> {
    if req.cancelled.load(Ordering::Relaxed) {
        return Some(CancelReason::Client);
    }
    if let Some(deadline) = req.params.deadline {
        if req.submitted.elapsed() > deadline {
            return Some(CancelReason::Deadline);
        }
    }
    None
}

fn retire_cancelled(req: GenRequest, reason: CancelReason, metrics: &mut ServeMetrics) {
    metrics.cancelled += 1;
    if reason == CancelReason::Deadline {
        metrics.deadline_expired += 1;
    }
    // the client may have dropped its handle already; delivery best-effort
    let _ = req.events.send(Event::Cancelled {
        id: req.id,
        reason,
    });
}

fn decode_loop(
    cfg: &Config,
    options: &ServerOptions,
    backend: &mut dyn ModelBackend,
    rx: &Receiver<GenRequest>,
    shared: &Shared,
    metrics: &mut ServeMetrics,
) -> Result<()> {
    let max_batch = if options.max_batch == 0 {
        cfg.batch
    } else {
        options.max_batch
    };
    // paged KV is opt-in *and* backend-negotiated: a backend that cannot
    // page (synthetic) declines, and admission stays queue-depth-only
    let paged: Option<PagedKvOptions> = match (&options.paged_kv, options.decode) {
        (Some(pk), DecodeMode::Cached) if backend.configure_paged(pk) => Some(pk.clone()),
        _ => None,
    };
    // KV blocks promised to admitted-but-not-yet-retired requests; the
    // admission invariant `kv_committed ≤ pool capacity` is what makes
    // block reservations on behalf of admitted work infallible
    let mut kv_committed = 0usize;
    crate::log_debug!(
        "serve: decoding '{}' via '{}' ({:?}, max_batch {max_batch}, max_queue {}, paged {})",
        cfg.name,
        backend.artifact(),
        options.decode,
        shared.max_queue,
        paged.is_some(),
    );

    let mut slots: Vec<Slot> = Vec::new();
    // the worker-owned view of the admission queue: pulled eagerly from the
    // channel so queued requests can be cancel/deadline-swept every
    // iteration even while all decode slots are busy
    let mut pending: VecDeque<GenRequest> = VecDeque::new();
    let mut queue_open = true;
    // wall-clock window for throughput: decode only, excluding backend
    // construction/warmup (which happened before this call)
    let start = Instant::now();

    while queue_open || !slots.is_empty() || !pending.is_empty() {
        // pull everything submitted so far
        loop {
            match rx.try_recv() {
                Ok(req) => pending.push_back(req),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    queue_open = false;
                    break;
                }
            }
        }

        // sweep the queue: client cancels and expired deadlines must not
        // wait for a free decode slot
        let mut i = 0;
        while i < pending.len() {
            match cancel_reason(&pending[i]) {
                Some(reason) => {
                    let Some(req) = pending.remove(i) else { break };
                    shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    retire_cancelled(req, reason, metrics);
                }
                None => i += 1,
            }
        }

        // admit into free decode slots (FIFO); nothing-to-generate
        // requests complete immediately without spending a slot
        let mut prefills_this_tick = 0usize;
        while slots.len() < max_batch {
            let Some(req) = pending.pop_front() else { break };
            // memory-aware admission (paged backends): project the
            // request's worst-case block footprint before seating it
            let kv_projection = match &paged {
                Some(pk) if req.params.max_new_tokens > 0 => {
                    let needed = kv_block_projection(&req, options, cfg, pk);
                    if needed > pk.blocks {
                        // can never fit the pool, at any load: reject now
                        // instead of stranding it in the queue forever
                        shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        metrics.kv_pressure_rejected += 1;
                        retire_cancelled(req, CancelReason::KvPressure, metrics);
                        continue;
                    }
                    if kv_committed + needed > pk.blocks {
                        // fits eventually, not now: keep it queued (still
                        // counted in queue_depth) until blocks free up
                        pending.push_front(req);
                        break;
                    }
                    needed
                }
                _ => 0,
            };
            shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
            if req.params.max_new_tokens == 0 {
                let latency = req.submitted.elapsed().as_secs_f64();
                // no token is emitted, so contribute no TTFT sample
                metrics.latencies.push(latency);
                let _ = req.events.send(Event::Done(GenResponse {
                    id: req.id,
                    text: String::new(),
                    tokens_generated: 0,
                    ttft: latency,
                    latency,
                }));
                continue;
            }
            // seat the request: absorb its whole prompt now — one cached
            // prefill pass (or one oracle recompute) — and hold the
            // resulting logits row for this iteration's sampling
            let mut slot = new_slot(req);
            slot.kv_projection = kv_projection;
            kv_committed += kv_projection;
            // the context cap clamps the *prompt* too (keeping the most
            // recent tokens, the old decode window's semantics): it must
            // bound the prefill cost and the KV allocation themselves,
            // not just generation
            if options.max_context > 0 && slot.tokens.len() > options.max_context {
                let cut = slot.tokens.len() - options.max_context;
                slot.tokens.drain(..cut);
                slot.prompt_len = slot.tokens.len();
            }
            let mut reused = 0usize;
            let seeded = match options.decode {
                DecodeMode::Cached => backend.prefill(&slot.tokens).map(|pf| {
                    slot.session = Some(pf.session);
                    reused = pf.reused;
                    pf.logits
                }),
                DecodeMode::Recompute => backend.oracle_logits(&slot.tokens),
            };
            match seeded {
                Ok(logits) => {
                    // prefix-cache hits skip the shared span's forward
                    // passes entirely; count only the work actually done
                    metrics.prefill_tokens += slot.prompt_len - reused;
                    if paged.as_ref().is_some_and(|pk| pk.prefix_cache) {
                        metrics.prefix_lookups += 1;
                        if reused > 0 {
                            metrics.prefix_hits += 1;
                        }
                        metrics.prefix_tokens_reused += reused;
                    }
                    slot.next_logits = logits;
                    slots.push(slot);
                }
                Err(e) => {
                    // per-request failure: retire this request and keep
                    // serving the others — one bad prompt must not take
                    // down the worker. Block-pool exhaustion mid-prefill
                    // (possible only if the projection under-counted)
                    // surfaces as KvPressure so clients see 429, not 500.
                    kv_committed -= slot.kv_projection;
                    let reason = if e.downcast_ref::<KvPressure>().is_some() {
                        metrics.kv_pressure_rejected += 1;
                        CancelReason::KvPressure
                    } else {
                        CancelReason::Backend
                    };
                    crate::log_warn!(
                        "serve: prefill failed for request {}: {e:#}",
                        slot.req.id
                    );
                    retire_cancelled(slot.req, reason, metrics);
                }
            }
            // bounded prefill attempts per iteration (default 1): a burst
            // of queued long prompts must interleave with decode steps,
            // not stall token emission for every already-active session.
            // `prefill_per_tick: 0` drains the queue — the right shape
            // when prefill is cheap and the arrival rate is high (the
            // HTTP front door's load-test configuration).
            prefills_this_tick += 1;
            if options.prefill_per_tick != 0 && prefills_this_tick >= options.prefill_per_tick {
                break;
            }
        }

        if slots.is_empty() {
            if pending.is_empty() {
                if !queue_open {
                    break;
                }
                // idle: block briefly for the next request
                match rx.recv_timeout(options.poll_interval) {
                    Ok(req) => pending.push_back(req),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => queue_open = false,
                }
            }
            // pending work left (e.g. after a failed prefill): loop
            // straight back into admission without sleeping
            continue;
        }

        // retire cancelled / past-deadline slots before spending a forward
        // pass on them — this is where a dropped Completion frees its slot
        let mut row = 0;
        while row < slots.len() {
            match cancel_reason(&slots[row].req) {
                Some(reason) => {
                    let slot = slots.swap_remove(row);
                    kv_committed -= slot.kv_projection;
                    retire_cancelled(slot.req, reason, metrics);
                }
                None => row += 1,
            }
        }
        if slots.is_empty() {
            continue;
        }

        metrics.batch_sizes.push(slots.len() as f64);
        metrics
            .queue_depths
            .push(shared.queue_depth.load(Ordering::Relaxed) as f64);

        // phase 1 — sample each slot's held logits and stream the token;
        // rows that just finished (token budget, stop sequence, context
        // cap) retire without spending any more backend work
        // rows to retire: None = completed normally, Some = cancelled
        let mut retire: Vec<(usize, Option<CancelReason>)> = Vec::new();
        let mut advance = vec![false; slots.len()];
        for (row, slot) in slots.iter_mut().enumerate() {
            let params = &slot.req.params;
            let next = slot
                .rng
                .sample_logits_topk(&slot.next_logits, params.temperature, params.top_k)
                as i32;
            slot.tokens.push(next);
            let ch = next as u8 as char;
            slot.gen_text.push(ch);
            let index = slot.tokens.len() - slot.prompt_len - 1;

            // first-token emission defines TTFT
            let at = slot.req.submitted.elapsed().as_secs_f64();
            if slot.ttft.is_none() {
                slot.ttft = Some(at);
            }
            let _ = slot.req.events.send(Event::Token(TokenEvent {
                id: slot.req.id,
                index,
                ch,
                at,
            }));

            let generated = index + 1;
            let stopped = params
                .stop_sequences
                .iter()
                .any(|s| !s.is_empty() && slot.gen_text.ends_with(s.as_str()));
            let capped =
                options.max_context > 0 && slot.tokens.len() >= options.max_context;
            if generated >= params.max_new_tokens || stopped || capped {
                retire.push((row, None));
            } else {
                advance[row] = true;
            }
        }

        // phase 2 — advance every still-running slot: one stacked
        // `decode_batch` call per tick on the cached path (per-row
        // failures retire only their own slot), or one oracle recompute
        // per slot on the baseline path. The cache-exactness and
        // row-equality contracts keep all paths token-identical.
        match options.decode {
            DecodeMode::Cached => {
                let mut rows: Vec<usize> = Vec::new();
                let mut toks: Vec<i32> = Vec::new();
                let mut sessions: Vec<&mut Session> = Vec::new();
                for (row, slot) in slots.iter_mut().enumerate() {
                    if !advance[row] {
                        continue;
                    }
                    // an empty token buffer or a missing session on the
                    // cached path is an internal-state bug; retire that
                    // row through the backend-failure path instead of
                    // panicking the worker
                    let (Some(&tok), Some(session)) =
                        (slot.tokens.last(), slot.session.as_mut())
                    else {
                        retire.push((row, Some(CancelReason::Backend)));
                        continue;
                    };
                    rows.push(row);
                    toks.push(tok);
                    sessions.push(session);
                }
                if !sessions.is_empty() {
                    metrics.decode_batches += 1;
                    metrics.decode_batch_rows.push(sessions.len() as f64);
                    let mut results = backend.decode_batch(&mut sessions, &toks);
                    drop(sessions);
                    if results.len() != rows.len() {
                        // defensive against a misbehaving third-party
                        // backend: missing rows retire, surplus rows drop
                        crate::log_warn!(
                            "serve: decode_batch returned {} rows for {} sessions",
                            results.len(),
                            rows.len()
                        );
                        results.truncate(rows.len());
                        results.resize_with(rows.len(), || {
                            Err(anyhow::anyhow!("decode_batch dropped this row"))
                        });
                    }
                    for (row, result) in rows.into_iter().zip(results) {
                        match result {
                            Ok(logits) => {
                                metrics.decode_tokens += 1;
                                slots[row].next_logits = logits;
                            }
                            Err(e) => {
                                // per-request failure: retire only this
                                // slot; mid-decode pool exhaustion (only
                                // possible if the admission projection
                                // under-counted) stays typed as pressure
                                let reason = if e.downcast_ref::<KvPressure>().is_some() {
                                    CancelReason::KvPressure
                                } else {
                                    CancelReason::Backend
                                };
                                crate::log_warn!(
                                    "serve: decode step failed for request {}: {e:#}",
                                    slots[row].req.id
                                );
                                retire.push((row, Some(reason)));
                            }
                        }
                    }
                }
            }
            DecodeMode::Recompute => {
                for (row, slot) in slots.iter_mut().enumerate() {
                    if !advance[row] {
                        continue;
                    }
                    match backend.oracle_logits(&slot.tokens) {
                        Ok(logits) => {
                            metrics.decode_tokens += 1;
                            slot.next_logits = logits;
                        }
                        Err(e) => {
                            crate::log_warn!(
                                "serve: decode step failed for request {}: {e:#}",
                                slot.req.id
                            );
                            retire.push((row, Some(CancelReason::Backend)));
                        }
                    }
                }
            }
        }
        // KV residency after this iteration's appends (all zeros in
        // recompute mode — no sessions exist)
        metrics.cache_bytes.push(
            slots
                .iter()
                .map(|s| s.session.as_ref().map_or(0, Session::kv_bytes))
                .sum::<usize>() as f64,
        );
        // paged-pool residency in blocks (shared prefix blocks counted
        // once — the pool tracks physical, not per-session, occupancy)
        if let Some(stats) = backend.kv_pool_stats() {
            metrics.kv_blocks_in_use.push(stats.in_use as f64);
        }
        // phase-1 (finished) and phase-2 (backend-failed) retirements
        // interleave, so order by row and swap_remove highest-first so
        // earlier indices stay valid
        retire.sort_unstable_by_key(|&(row, _)| row);
        for &(row, cancelled) in retire.iter().rev() {
            let slot = slots.swap_remove(row);
            kv_committed -= slot.kv_projection;
            if let Some(reason) = cancelled {
                if reason == CancelReason::KvPressure {
                    metrics.kv_pressure_rejected += 1;
                }
                retire_cancelled(slot.req, reason, metrics);
                continue;
            }
            let latency = slot.req.submitted.elapsed().as_secs_f64();
            let gen_tokens = slot.tokens.len() - slot.prompt_len;
            let ttft = slot.ttft.unwrap_or(latency);
            metrics.record(ttft, latency, gen_tokens);
            let _ = slot.req.events.send(Event::Done(GenResponse {
                id: slot.req.id,
                text: slot.gen_text,
                tokens_generated: gen_tokens,
                ttft,
                latency,
            }));
        }
    }
    // drain complete: every slot has retired, so after dropping the
    // prefix trie the pool must be empty — anything still in use is a
    // leaked block (surfaced, not panicked, so metrics reach the caller)
    if paged.is_some() {
        backend.kv_reset();
        if let Some(stats) = backend.kv_pool_stats() {
            metrics.kv_blocks_capacity = stats.capacity;
            metrics.kv_peak_blocks = stats.peak;
            metrics.kv_evictions = stats.evictions;
            metrics.kv_blocks_leaked = stats.in_use;
            if stats.in_use > 0 {
                crate::log_warn!("serve: {} kv block(s) leaked at drain", stats.in_use);
            }
        }
    }
    metrics.wall_secs = start.elapsed().as_secs_f64();
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::init::init_params;

    #[test]
    fn serves_batched_requests_end_to_end() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let server = Server::start(cfg.clone(), ServedModel::Dense(params));
        let completions: Vec<_> = (0..6)
            .map(|i| {
                server
                    .submit(
                        &format!("the cat {i}"),
                        GenParams {
                            max_new_tokens: 5,
                            ..Default::default()
                        },
                    )
                    .expect("queue has room")
            })
            .collect();
        let mut total = 0;
        for c in completions {
            let resp = c.wait_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens_generated, 5);
            // text is chars-from-bytes; high bytes widen to 2 utf-8 bytes
            assert_eq!(resp.text.chars().count(), 5);
            assert!(resp.latency >= resp.ttft);
            total += resp.tokens_generated;
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.tokens, total);
        // prefill/decode accounting: six "the cat N" prompts (9 bytes
        // each) and 4 cached steps per 5-token completion
        assert_eq!(metrics.prefill_tokens, 6 * 9);
        assert_eq!(metrics.decode_tokens, 6 * 4);
        assert!(metrics.peak_cache_bytes() > 0.0);
    }

    #[test]
    fn greedy_decode_is_deterministic_per_run() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let server = Server::start(cfg.clone(), ServedModel::Dense(params));
        let p = GenParams {
            max_new_tokens: 8,
            temperature: 0.0,
            ..Default::default()
        };
        let a = server.submit("hello", p.clone()).unwrap().wait().unwrap();
        let b = server.submit("hello", p).unwrap().wait().unwrap();
        assert_eq!(a.text, b.text);
        server.shutdown();
    }

    #[test]
    fn cached_ticks_issue_one_batched_call_each() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        let server = Server::start_with(
            cfg.clone(),
            ServedModel::Dense(params),
            ServerOptions {
                max_batch: 4,
                ..Default::default()
            },
        );
        let completions: Vec<_> = (0..4)
            .map(|i| {
                server
                    .submit(
                        &format!("req {i}"),
                        GenParams {
                            max_new_tokens: 64,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            })
            .collect();
        for c in completions {
            c.wait_timeout(Duration::from_secs(60)).unwrap();
        }
        let m = server.shutdown();
        assert!(m.decode_batches > 0);
        assert_eq!(m.decode_batches, m.decode_batch_rows.len());
        // every advanced row came through a batched call, none failed
        assert_eq!(
            m.decode_batch_rows.iter().sum::<f64>() as usize,
            m.decode_tokens
        );
        // occupancy never exceeds the slot budget, and with 4 long-lived
        // requests the batch fills all 4 slots at some tick
        let max_rows = m.decode_batch_rows.iter().cloned().fold(0.0, f64::max);
        assert!(max_rows <= 4.0);
        assert_eq!(max_rows, 4.0, "batch never filled: {:?}", m.decode_batch_rows);
    }

    #[test]
    fn options_default_bounds() {
        let o = ServerOptions::default();
        assert!(o.max_queue >= 1);
        assert_eq!(o.max_batch, 0); // = cfg.batch
        assert!(o.poll_interval > Duration::ZERO);
        assert_eq!(o.decode, DecodeMode::Cached);
        assert_eq!(o.max_context, 0); // unlimited unless the operator caps it
        assert_eq!(o.prefill_per_tick, 1); // historical one-prefill-per-tick
        assert!(o.paged_kv.is_none()); // dense per-session caches unless opted in
    }

    #[test]
    fn paged_pool_never_fits_rejects_with_kv_pressure() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(5));
        // 4 blocks × 4 tokens across 2 layers = at most 8 tokens of
        // context per layer chain; a 9-byte prompt + 64 new tokens can
        // never fit, so admission must 429 it instead of queueing forever
        let server = Server::start_with(
            cfg.clone(),
            ServedModel::Dense(params),
            ServerOptions {
                paged_kv: Some(PagedKvOptions {
                    blocks: 4,
                    block_tokens: 4,
                    prefix_cache: true,
                }),
                ..Default::default()
            },
        );
        let doomed = server
            .submit(
                "the cat sat on the mat",
                GenParams {
                    max_new_tokens: 64,
                    ..Default::default()
                },
            )
            .unwrap();
        match doomed.wait_timeout(Duration::from_secs(60)) {
            Err(WaitError::Cancelled(CancelReason::KvPressure)) => {}
            other => panic!("expected KvPressure cancellation, got {other:?}"),
        }
        // a small request still fits the same pool and completes
        let ok = server
            .submit(
                "hi",
                GenParams {
                    max_new_tokens: 4,
                    ..Default::default()
                },
            )
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .unwrap();
        assert_eq!(ok.tokens_generated, 4);
        let m = server.shutdown();
        assert_eq!(m.kv_pressure_rejected, 1);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.kv_blocks_leaked, 0, "blocks leaked at drain");
        assert!(m.kv_peak_blocks <= m.kv_blocks_capacity);
        assert_eq!(m.kv_blocks_capacity, 4);
    }

    #[test]
    fn paged_pressure_queues_until_blocks_free_up() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(6));
        // each request projects 2 layers × ceil((7+8)/8) = 4 blocks; a
        // 9-block pool seats two at a time, so six requests must take
        // turns through memory-aware admission — and all still finish
        let server = Server::start_with(
            cfg.clone(),
            ServedModel::Dense(params),
            ServerOptions {
                paged_kv: Some(PagedKvOptions {
                    blocks: 9,
                    block_tokens: 8,
                    prefix_cache: true,
                }),
                prefill_per_tick: 0,
                ..Default::default()
            },
        );
        let completions: Vec<_> = (0..6)
            .map(|i| {
                server
                    .submit(
                        &format!("press {i}"),
                        GenParams {
                            max_new_tokens: 8,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            })
            .collect();
        for c in completions {
            let resp = c.wait_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens_generated, 8);
        }
        let m = server.shutdown();
        assert_eq!(m.latencies.len(), 6);
        assert_eq!(m.kv_pressure_rejected, 0);
        assert_eq!(m.kv_blocks_leaked, 0);
        // committed admission keeps physical residency within the pool
        assert!(m.kv_peak_blocks <= 9, "peak {} > capacity", m.kv_peak_blocks);
    }

    #[test]
    fn synthetic_backend_declines_paging_and_serves_normally() {
        let cfg = Config::builtin("tiny").unwrap();
        let backend_cfg = cfg.clone();
        let server = Server::with_backend(
            cfg,
            ServerOptions {
                paged_kv: Some(PagedKvOptions {
                    blocks: 1, // would reject everything if enforced
                    block_tokens: 1,
                    prefix_cache: true,
                }),
                ..Default::default()
            },
            move || {
                Ok(Box::new(super::super::backend::SyntheticBackend::new(
                    backend_cfg,
                )))
            },
        );
        let resp = server
            .submit(
                "synthetic ignores paging",
                GenParams {
                    max_new_tokens: 6,
                    ..Default::default()
                },
            )
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .unwrap();
        assert_eq!(resp.tokens_generated, 6);
        let m = server.shutdown();
        // the backend declined: no pool, no kv accounting
        assert_eq!(m.kv_blocks_capacity, 0);
        assert_eq!(m.kv_pressure_rejected, 0);
    }

    #[test]
    fn submitter_clones_share_ids_and_admission_queue() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(4));
        let server = Server::start(cfg.clone(), ServedModel::Dense(params));
        let sub = server.submitter().unwrap();
        let twin = sub.clone();
        let p = GenParams {
            max_new_tokens: 3,
            ..Default::default()
        };
        let a = sub.submit("one", p.clone()).unwrap();
        let b = twin.submit("two", p.clone()).unwrap();
        let c = server.submit("three", p).unwrap();
        // one shared id counter across every handle
        let mut ids = vec![a.id(), b.id(), c.id()];
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3);
        for handle in [a, b, c] {
            let resp = handle.wait_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens_generated, 3);
        }
        // dropping every submitter clone lets shutdown drain normally
        drop(sub);
        drop(twin);
        let m = server.shutdown();
        assert_eq!(m.latencies.len(), 3);
    }

    #[test]
    fn prefill_per_tick_zero_drains_the_queue() {
        let cfg = Config::builtin("tiny").unwrap();
        let backend_cfg = cfg.clone();
        let server = Server::with_backend(
            cfg,
            ServerOptions {
                max_batch: 16,
                prefill_per_tick: 0,
                ..Default::default()
            },
            move || {
                // free prefill + a paced decode tick: all 12 submissions
                // land within the first tick or two, so drain-mode
                // admission provably stacks them into one batch
                Ok(Box::new(super::super::backend::SyntheticBackend::with_delays(
                    backend_cfg,
                    Duration::ZERO,
                    Duration::from_millis(2),
                )))
            },
        );
        let completions: Vec<_> = (0..12)
            .map(|i| {
                server
                    .submit(
                        &format!("r{i}"),
                        GenParams {
                            max_new_tokens: 16,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            })
            .collect();
        for c in completions {
            c.wait_timeout(Duration::from_secs(60)).unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.latencies.len(), 12);
        // draining admission lets the batch fill well past one-per-tick
        let max_rows = m.decode_batch_rows.iter().cloned().fold(0.0, f64::max);
        assert!(max_rows >= 10.0, "queue not drained: {:?}", m.decode_batch_rows);
    }
}
