//! Serving example: load (or build) a compressed model and serve a Poisson
//! arrival stream of generation requests through the continuous-batching
//! engine, reporting tail latency and throughput vs the dense model.

use aasvd::compress::{compress_model, Method};
use aasvd::serve::batcher::{bench_prompts, poisson_arrivals};
use aasvd::serve::{GenParams, ServedModel, Server};
use aasvd::experiments::{setup, Knobs};
use aasvd::util::cli::Args;
use anyhow::Result;
use std::time::{Duration, Instant};

fn drive(server: &Server, n: usize, rate: f64) -> Result<aasvd::serve::ServeMetrics> {
    let prompts = bench_prompts(n, 11);
    let arrivals = poisson_arrivals(n, rate, 13);
    let start = Instant::now();
    let mut receivers = Vec::new();
    for (p, &at) in prompts.iter().zip(&arrivals) {
        let now = start.elapsed().as_secs_f64();
        if at > now {
            std::thread::sleep(Duration::from_secs_f64(at - now));
        }
        receivers.push(server.submit(
            p,
            GenParams {
                max_new_tokens: 16,
                temperature: 0.8,
                stop_byte: Some(b'.'),
            },
        ));
    }
    for rx in receivers {
        rx.recv()?;
    }
    Ok(aasvd::serve::ServeMetrics::default()) // final metrics via shutdown
}

fn main() -> Result<()> {
    let args = Args::parse_env("serve a compressed model under Poisson load");
    let knobs = Knobs::parse(&args, "small");
    let n = args.usize("requests", 40, "number of requests");
    let rate = args.f64("rate", 8.0, "arrival rate (req/s)");
    let ratio = args.f64("ratio", 0.6, "compression ratio");
    args.finish_or_help();

    let ctx = setup(&knobs)?;
    println!("[serve] compressing {} @ {ratio} with aa_svd...", ctx.cfg.name);
    let cm = compress_model(
        &ctx.engine,
        &ctx.cfg,
        &ctx.params,
        &ctx.calib,
        &Method::aa_svd(knobs.refine()),
        ratio,
    )?;

    for (label, model) in [
        ("dense", ServedModel::Dense(ctx.params.clone())),
        (
            "aa_svd",
            ServedModel::Compressed(ctx.params.clone(), cm.blocks.clone()),
        ),
    ] {
        let server = Server::start("artifacts".into(), ctx.cfg.clone(), model);
        drive(&server, n, rate)?;
        let metrics = server.shutdown();
        println!("[{label}] {}", metrics.summary());
    }
    Ok(())
}
