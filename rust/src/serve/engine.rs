//! Serving engine: a worker thread owning the PJRT engine runs a
//! continuous-batching decode loop; callers submit prompts over a channel
//! and receive completions asynchronously.
//!
//! Decode strategy: windowed re-forward. Each iteration packs every active
//! request's most recent ≤T tokens into one [B, T] batch, runs the
//! model(-lr)_fwd artifact, samples one token per request from the logits
//! at its own length position, and admits/retires requests between
//! iterations (vLLM-style continuous batching at sequence granularity —
//! the batch never drains to refill). KV caching through the PJRT boundary
//! would round-trip the full cache per step through host literals, which
//! measures slower than re-forward at these model sizes; see DESIGN.md.

use super::metrics::ServeMetrics;
use super::request::{GenParams, GenRequest, GenResponse};
use crate::model::lowrank::{concat_factors, BlockFactors};
use crate::model::{Config, FlatStore};
use crate::runtime::{Engine, Value};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// What the server is serving.
pub enum ServedModel {
    Dense(FlatStore),
    Compressed(FlatStore, Vec<BlockFactors>),
}

pub struct Server {
    tx: Option<Sender<GenRequest>>,
    next_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<ServeMetrics>>,
}

struct Slot {
    req: GenRequest,
    tokens: Vec<i32>,
    prompt_len: usize,
    ttft: Option<f64>,
}

impl Server {
    /// Start the worker. `artifact_dir` is compiled inside the worker
    /// thread (the PJRT client is not Sync).
    pub fn start(artifact_dir: String, cfg: Config, model: ServedModel) -> Server {
        let (tx, rx) = channel::<GenRequest>();
        let worker = std::thread::Builder::new()
            .name("aasvd-serve".into())
            .spawn(move || decode_loop(&artifact_dir, &cfg, &model, rx).unwrap())
            .expect("spawn serve worker");
        Server {
            tx: Some(tx),
            next_id: AtomicU64::new(1),
            worker: Some(worker),
        }
    }

    /// Submit a prompt; returns a receiver for the completion.
    pub fn submit(&self, prompt: &str, params: GenParams) -> Receiver<GenResponse> {
        let (resp_tx, resp_rx) = channel();
        let req = GenRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            prompt: prompt.to_string(),
            params,
            submitted: Instant::now(),
            respond: resp_tx,
        };
        self.tx
            .as_ref()
            .expect("server shut down")
            .send(req)
            .expect("serve worker gone");
        resp_rx
    }

    /// Close the queue, drain in-flight requests, collect final metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.tx.take(); // disconnect: worker drains and exits
        let worker = self.worker.take().unwrap();
        worker.join().expect("serve worker panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take(); // must disconnect BEFORE joining or the worker spins
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn decode_loop(
    artifact_dir: &str,
    cfg: &Config,
    model: &ServedModel,
    rx: Receiver<GenRequest>,
) -> Result<ServeMetrics> {
    let engine = Engine::new(artifact_dir)?;
    let (b, t, vocab) = (cfg.batch, cfg.seq, cfg.vocab);
    let artifact = match model {
        ServedModel::Dense(_) => "model_fwd",
        ServedModel::Compressed(..) => "model_lr_fwd",
    };
    engine.warmup(&cfg.name, &[artifact])?;
    let precomputed = match model {
        ServedModel::Dense(_) => None,
        ServedModel::Compressed(_, blocks) => Some(concat_factors(blocks)),
    };

    let mut slots: Vec<Slot> = Vec::new();
    let mut metrics = ServeMetrics::default();
    let mut rng = Rng::new(0xd00d);
    let mut queue_open = true;
    let start = Instant::now();

    while queue_open || !slots.is_empty() {
        // admit
        while slots.len() < b {
            match rx.try_recv() {
                Ok(req) => slots.push(new_slot(req)),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    queue_open = false;
                    break;
                }
            }
        }
        if slots.is_empty() {
            if !queue_open {
                break;
            }
            // idle: block briefly for the next request
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(req) => slots.push(new_slot(req)),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => queue_open = false,
            }
            continue;
        }
        metrics.batch_sizes.push(slots.len() as f64);

        // pack the batch: window = last min(len, t) tokens, end-padded
        let mut tokens = vec![b' ' as i32; b * t];
        let mut read_pos = vec![0usize; slots.len()];
        for (row, slot) in slots.iter().enumerate() {
            let window: &[i32] = if slot.tokens.len() <= t {
                &slot.tokens
            } else {
                &slot.tokens[slot.tokens.len() - t..]
            };
            tokens[row * t..row * t + window.len()].copy_from_slice(window);
            read_pos[row] = window.len() - 1;
        }

        let logits = match (model, &precomputed) {
            (ServedModel::Dense(params), _) => engine.run(
                &cfg.name,
                "model_fwd",
                &[Value::F32(&params.data), Value::I32(&tokens)],
            )?,
            (ServedModel::Compressed(params, _), Some((fs, ms))) => engine.run(
                &cfg.name,
                "model_lr_fwd",
                &[
                    Value::F32(&params.data),
                    Value::F32(fs),
                    Value::F32(ms),
                    Value::I32(&tokens),
                ],
            )?,
            _ => unreachable!(),
        };

        // sample + retire
        let mut done: Vec<usize> = Vec::new();
        for (row, slot) in slots.iter_mut().enumerate() {
            let base = (row * t + read_pos[row]) * vocab;
            let row_logits = &logits[0].f32[base..base + vocab];
            let next = rng.sample_logits(row_logits, slot.req.params.temperature) as i32;
            slot.tokens.push(next);
            if slot.ttft.is_none() {
                slot.ttft = Some(slot.req.submitted.elapsed().as_secs_f64());
            }
            let generated = slot.tokens.len() - slot.prompt_len;
            let stopped = slot
                .req
                .params
                .stop_byte
                .map(|s| next == s as i32)
                .unwrap_or(false);
            if generated >= slot.req.params.max_new_tokens || stopped {
                done.push(row);
            }
        }
        for &row in done.iter().rev() {
            let slot = slots.swap_remove(row);
            let latency = slot.req.submitted.elapsed().as_secs_f64();
            let gen_tokens = slot.tokens.len() - slot.prompt_len;
            let text: String = slot.tokens[slot.prompt_len..]
                .iter()
                .map(|&x| x as u8 as char)
                .collect();
            metrics.record(slot.ttft.unwrap_or(latency), latency, gen_tokens);
            let _ = slot.req.respond.send(GenResponse {
                id: slot.req.id,
                text,
                tokens_generated: gen_tokens,
                ttft: slot.ttft.unwrap_or(latency),
                latency,
            });
        }
    }
    metrics.wall_secs = start.elapsed().as_secs_f64();
    Ok(metrics)
}

fn new_slot(req: GenRequest) -> Slot {
    let tokens: Vec<i32> = req.prompt.bytes().map(|x| x as i32).collect();
    let tokens = if tokens.is_empty() {
        vec![b' ' as i32]
    } else {
        tokens
    };
    Slot {
        prompt_len: tokens.len(),
        tokens,
        req,
        ttft: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;

    #[test]
    fn serves_batched_requests_end_to_end() {
        if Engine::new("artifacts")
            .map(|e| e.entry("tiny").is_err())
            .unwrap_or(true)
        {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let server = Server::start(
            "artifacts".into(),
            cfg.clone(),
            ServedModel::Dense(params),
        );
        let receivers: Vec<_> = (0..6)
            .map(|i| {
                server.submit(
                    &format!("the cat {i}"),
                    GenParams {
                        max_new_tokens: 5,
                        ..Default::default()
                    },
                )
            })
            .collect();
        let mut total = 0;
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.tokens_generated, 5);
            // text is chars-from-bytes; high bytes widen to 2 utf-8 bytes
            assert_eq!(resp.text.chars().count(), 5);
            assert!(resp.latency >= resp.ttft);
            total += resp.tokens_generated;
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.tokens, total);
        // continuous batching actually batched something
        assert!(metrics.mean_batch_occupancy() > 1.0);
    }

    #[test]
    fn greedy_decode_is_deterministic_per_run() {
        if Engine::new("artifacts")
            .map(|e| e.entry("tiny").is_err())
            .unwrap_or(true)
        {
            return;
        }
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let server = Server::start(
            "artifacts".into(),
            cfg.clone(),
            ServedModel::Dense(params),
        );
        let p = GenParams {
            max_new_tokens: 8,
            temperature: 0.0,
            stop_byte: None,
        };
        let a = server.submit("hello", p.clone()).recv().unwrap();
        let b = server.submit("hello", p).recv().unwrap();
        assert_eq!(a.text, b.text);
        server.shutdown();
    }
}
