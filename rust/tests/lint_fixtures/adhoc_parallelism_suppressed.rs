// aasvd-lint: path=src/compress/fixture.rs

pub fn fan_out() -> i32 {
    // aasvd-lint: allow(adhoc-parallelism): fixture justification — pretend this is a sanctioned long-lived worker
    let handle = std::thread::spawn(|| 1 + 1);
    handle.join().unwrap_or(0)
}
