//! End-to-end contract tests for the int8 quantized serving backend
//! (see README "Quantized serving"):
//!
//! - the fused dequant kernels are **bitwise identical** to the
//!   dequantize-then-f32 oracle at every tested thread count and batch
//!   size — int8 storage must never change what gets computed, only
//!   where the bytes live;
//! - `QuantizedBackend` honors the decode_batch row contract (each
//!   batched row bitwise equals its `decode_step` twin) and is bitwise
//!   thread-count invariant;
//! - the AAT2 quantized artifact round-trips exactly, and a backend
//!   built from a reloaded artifact decodes bitwise like the original;
//! - the backend survives randomized engine schedules (admit / cancel /
//!   deadline churn) with the engine's lifecycle invariants intact;
//! - the quantized model's perplexity stays within a small bound of the
//!   f32 compressed model it was quantized from.

use aasvd::data::{Batcher, Corpus, Domain};
use aasvd::eval::{lowrank_ppl, quant_ppl};
use aasvd::model::forward::{linear_batch, qlinear_batch};
use aasvd::model::init::init_params;
use aasvd::model::lowrank::{exact_factors, BlockFactors};
use aasvd::model::quant_lowrank::{load_quant_blocks, save_quant_blocks, QuantBlockFactors};
use aasvd::model::{Config, FlatStore};
use aasvd::serve::{
    DecodeMode, Event, GenParams, GenResponse, ModelBackend, QuantizedBackend, Server,
    ServerOptions, Session, SubmitError,
};
use aasvd::util::pool::Pool;
use aasvd::util::rng::Rng;
use std::time::Duration;

fn setup(seed: u64) -> (Config, FlatStore, Vec<BlockFactors>, Vec<QuantBlockFactors>) {
    let cfg = Config::builtin("tiny").unwrap();
    let params = init_params(&cfg, &mut Rng::new(seed));
    let blocks: Vec<_> = (0..cfg.n_layers)
        .map(|i| exact_factors(&cfg, &params, i))
        .collect();
    let qblocks: Vec<_> = blocks
        .iter()
        .map(|bf| QuantBlockFactors::from_block(&cfg, bf).unwrap())
        .collect();
    (cfg, params, blocks, qblocks)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: bit mismatch at {i}: {x} vs {y}"
        );
    }
}

/// The tentpole contract at the kernel boundary: the fused int8 matvec
/// equals dequantize-then-`linear_batch` bit for bit, at every tested
/// (threads, batch) point.
#[test]
fn fused_kernel_matches_dequant_oracle_across_threads_and_batch() {
    use aasvd::compress::QuantMatrix;
    let (m, n) = (48, 36);
    let mut rng = Rng::new(41);
    let w: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
    let q = QuantMatrix::quantize(&w, m, n).unwrap();
    let dw = q.dequantize();
    for threads in [1usize, 4] {
        let pool = Pool::exact(threads);
        for rows in [1usize, 8] {
            let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
            let mut fused = vec![0.0f32; rows * m];
            let mut oracle = vec![0.0f32; rows * m];
            qlinear_batch(&x, &q, &pool, &mut fused);
            linear_batch(&x, &dw, n, m, &pool, &mut oracle);
            assert_bits_eq(&fused, &oracle, &format!("t={threads} B={rows}"));
        }
    }
}

/// The decode_batch row contract and thread-count invariance of the
/// quantized backend at threads {1, 4} x B {1, 8}: every batched row is
/// bitwise its decode_step twin, and the logits do not move with the
/// worker count.
#[test]
fn quant_backend_rows_bitwise_stable_across_threads_and_batch() {
    let (cfg, params, _blocks, qblocks) = setup(11);
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for threads in [1usize, 4] {
        for rows in [1usize, 8] {
            let mut be_batch =
                QuantizedBackend::new(cfg.clone(), params.clone(), qblocks.clone()).unwrap();
            let mut be_seq =
                QuantizedBackend::new(cfg.clone(), params.clone(), qblocks.clone()).unwrap();
            let mut batched: Vec<Session> = (0..rows)
                .map(|r| be_batch.prefill(&[r as i32 + 1]).unwrap().session)
                .collect();
            let mut solo: Vec<Session> = (0..rows)
                .map(|r| be_seq.prefill(&[r as i32 + 1]).unwrap().session)
                .collect();
            let mut final_rows: Vec<Vec<f32>> = vec![Vec::new(); rows];
            for step in 0..6usize {
                let toks: Vec<i32> = (0..rows).map(|r| ((r * 13 + step * 5) % 200) as i32).collect();
                let out = Pool::exact(threads).install(|| {
                    let mut refs: Vec<&mut Session> = batched.iter_mut().collect();
                    be_batch.decode_batch(&mut refs, &toks)
                });
                for (r, row) in out.into_iter().enumerate() {
                    let row = row.unwrap();
                    let want = be_seq.decode_step(&mut solo[r], toks[r]).unwrap();
                    assert_bits_eq(
                        &row,
                        &want,
                        &format!("t={threads} B={rows} row {r} step {step}"),
                    );
                    final_rows[r] = row;
                }
            }
            // the B=8 logits must be identical at every thread count
            if rows == 8 {
                match &baseline {
                    None => baseline = Some(final_rows),
                    Some(base) => {
                        for (r, (a, b)) in base.iter().zip(&final_rows).enumerate() {
                            assert_bits_eq(a, b, &format!("thread-invariance row {r}"));
                        }
                    }
                }
            }
        }
    }
}

/// AAT2 artifact round-trip: reloaded blocks are field-for-field and
/// bit-for-bit the saved ones, and a backend built from them decodes
/// bitwise like a backend built from the originals.
#[test]
fn quant_artifact_roundtrips_and_decodes_identically() {
    let (cfg, params, _blocks, qblocks) = setup(23);
    let dir = std::env::temp_dir().join("aasvd-quantized-backend-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny_quant.aat");
    save_quant_blocks(&qblocks, &path).unwrap();
    let loaded = load_quant_blocks(&cfg, &path).unwrap();
    assert_eq!(loaded.len(), qblocks.len());
    for (a, b) in qblocks.iter().zip(&loaded) {
        assert_eq!(a.attn_norm, b.attn_norm);
        assert_eq!(a.mlp_norm, b.mlp_norm);
        for (la, lb) in a.linears.iter().zip(&b.linears) {
            for (qa, qb) in [(&la.u, &lb.u), (&la.v, &lb.v)] {
                assert_eq!(qa.rows, qb.rows);
                assert_eq!(qa.cols, qb.cols);
                assert_eq!(qa.group_rows, qb.group_rows);
                assert_eq!(qa.data, qb.data);
                assert_bits_eq(&qa.scales, &qb.scales, "scales");
            }
        }
    }

    let mut be_orig = QuantizedBackend::new(cfg.clone(), params.clone(), qblocks).unwrap();
    let mut be_load = QuantizedBackend::new(cfg.clone(), params.clone(), loaded).unwrap();
    let mut s_orig = be_orig.prefill(&[3, 7, 11]).unwrap();
    let mut s_load = be_load.prefill(&[3, 7, 11]).unwrap();
    assert_bits_eq(&s_orig.logits, &s_load.logits, "prefill logits");
    for tok in [5i32, 9, 2] {
        let a = be_orig.decode_step(&mut s_orig.session, tok).unwrap();
        let b = be_load.decode_step(&mut s_load.session, tok).unwrap();
        assert_bits_eq(&a, &b, "decode logits");
    }
}

/// The cached decode path through the quantized backend must match the
/// full-prefix recompute oracle token for token — speed means nothing if
/// the KV cache diverges over int8 factors.
#[test]
fn quant_cached_decode_matches_recompute_oracle() {
    let (cfg, params, _blocks, qblocks) = setup(31);
    let decode_one = |mode: DecodeMode| -> String {
        let backend_cfg = cfg.clone();
        let p = params.clone();
        let qb = qblocks.clone();
        let server = Server::with_backend(
            cfg.clone(),
            ServerOptions {
                decode: mode,
                ..Default::default()
            },
            move || {
                Ok(
                    Box::new(QuantizedBackend::new(backend_cfg.clone(), p.clone(), qb.clone())?)
                        as Box<dyn ModelBackend>,
                )
            },
        );
        let resp = server
            .submit(
                "the cat",
                GenParams {
                    max_new_tokens: 32,
                    temperature: 0.0,
                    ..Default::default()
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        server.shutdown();
        resp.text
    };
    assert_eq!(
        decode_one(DecodeMode::Cached),
        decode_one(DecodeMode::Recompute),
        "quantized cached decode diverged from the recompute oracle"
    );
}

/// Randomized engine schedules over the quantized backend: admit /
/// cancel / deadline churn must preserve the engine's lifecycle
/// invariants (exactly one terminal event per request, balanced
/// counters) with real int8 forwards underneath.
#[test]
fn quantized_backend_survives_randomized_schedules() {
    let (cfg, params, _blocks, qblocks) = setup(47);
    let mut rng = Rng::new(0x8B17_5EED);
    for schedule in 0..25u32 {
        let options = ServerOptions {
            max_batch: 1 + rng.below(4),
            max_queue: 1 + rng.below(6),
            poll_interval: Duration::from_millis(1),
            ..Default::default()
        };
        let backend_cfg = cfg.clone();
        let p = params.clone();
        let qb = qblocks.clone();
        let server = Server::with_backend(cfg.clone(), options, move || {
            Ok(
                Box::new(QuantizedBackend::new(backend_cfg.clone(), p.clone(), qb.clone())?)
                    as Box<dyn ModelBackend>,
            )
        });

        let n_requests = 1 + rng.below(6);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..n_requests {
            let prompt: String = (0..1 + rng.below(5))
                .map(|_| char::from(b'a' + rng.below(24) as u8))
                .collect();
            let gen = GenParams {
                max_new_tokens: rng.below(9),
                temperature: 0.0,
                deadline: if rng.below(6) == 0 {
                    Some(Duration::ZERO)
                } else {
                    None
                },
                ..Default::default()
            };
            match server.submit(&prompt, gen) {
                Ok(completion) => {
                    if rng.below(5) == 0 {
                        completion.cancel();
                    }
                    accepted.push(completion);
                }
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("schedule {schedule}: unexpected submit error: {e}"),
            }
        }

        let mut completed = 0usize;
        let mut cancelled = 0usize;
        for completion in accepted {
            let mut terminals = 0usize;
            let mut streamed = String::new();
            let mut done: Option<GenResponse> = None;
            while let Some(event) = completion.next_event() {
                match event {
                    Event::Token(t) => {
                        assert_eq!(
                            terminals, 0,
                            "schedule {schedule}: token after a terminal event"
                        );
                        streamed.push(t.ch);
                    }
                    Event::Done(resp) => {
                        terminals += 1;
                        done = Some(resp);
                    }
                    Event::Cancelled { .. } => terminals += 1,
                }
            }
            assert_eq!(
                terminals, 1,
                "schedule {schedule}: exactly one terminal event per request"
            );
            match done {
                Some(resp) => {
                    completed += 1;
                    assert_eq!(
                        resp.text, streamed,
                        "schedule {schedule}: final text vs streamed tokens"
                    );
                }
                None => cancelled += 1,
            }
        }

        let metrics = server.shutdown();
        assert_eq!(metrics.rejected, rejected, "schedule {schedule}: rejected");
        assert_eq!(
            n_requests,
            completed + cancelled + metrics.rejected,
            "schedule {schedule}: every submission has exactly one outcome"
        );
    }
}

/// Quantization is a compression step, not a lobotomy: the int8 model's
/// perplexity on a synthetic corpus stays within 10% of the f32
/// compressed model it was quantized from.
#[test]
fn quant_ppl_within_bound_of_f32_compressed() {
    let (cfg, params, blocks, qblocks) = setup(53);
    let corpus = Corpus::generate(Domain::Wiki, 20_000, 13);
    let batches: Vec<_> = Batcher::new(cfg.batch, cfg.seq).sequential(&corpus.valid, 2);
    assert!(!batches.is_empty());
    let lr = lowrank_ppl(&cfg, &params, &blocks, &batches);
    let q = quant_ppl(&cfg, &params, &qblocks, &batches);
    assert!(lr.is_finite() && q.is_finite(), "lowrank {lr} quant {q}");
    assert!(
        (q - lr).abs() <= 0.10 * lr,
        "quantized ppl {q} drifted beyond 10% of f32 compressed ppl {lr}"
    );
}
