//! Randomized property testing (proptest is unavailable offline).
//!
//! `check` runs a property over `n` PCG-seeded cases; on failure it reports
//! the failing case index and seed so the case can be replayed exactly with
//! `check_one`. A lightweight shrink pass retries the property on smaller
//! "size" hints to aid debugging of size-dependent failures.

use crate::util::rng::Rng;

/// Per-case context handed to properties: an RNG plus a size hint that
/// grows over the run (small cases first, like proptest).
pub struct Case {
    pub rng: Rng,
    pub size: usize,
    pub index: usize,
}

impl Case {
    /// Dimension helper in [1, size].
    pub fn dim(&mut self, cap: usize) -> usize {
        1 + self.rng.below(self.size.min(cap))
    }
}

/// Run `prop` over `n` random cases. Panics with replay info on failure.
pub fn check<F: FnMut(&mut Case)>(name: &str, n: usize, mut prop: F) {
    let base_seed = 0xAA5Du64;
    for index in 0..n {
        let size = 2 + (index * 62) / n.max(1); // ramp 2..64
        let seed = base_seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(index as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut case = Case {
                rng: Rng::new(seed),
                size,
                index,
            };
            prop(&mut case);
        }));
        if let Err(payload) = result {
            // shrink-lite: try the same seed with smaller sizes to find the
            // smallest size that still fails (purely informational)
            let mut min_fail = size;
            for s in (1..size).rev() {
                let again = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut case = Case {
                        rng: Rng::new(seed),
                        size: s,
                        index,
                    };
                    prop(&mut case);
                }));
                if again.is_err() {
                    min_fail = s;
                } else {
                    break;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {index} (seed {seed:#x}, \
                 size {size}, min failing size {min_fail}): {msg}"
            );
        }
    }
}

/// Replay a single case (use the seed printed by a `check` failure).
pub fn check_one<F: FnOnce(&mut Case)>(seed: u64, size: usize, prop: F) {
    let mut case = Case {
        rng: Rng::new(seed),
        size,
        index: 0,
    };
    prop(&mut case);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn failing_property_reports() {
        check("must-fail", 10, |c| {
            assert!(c.size < 5, "size grew");
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let mut sizes = Vec::new();
        check("sizes", 20, |c| sizes.push(c.size));
        assert!(sizes[0] <= sizes[sizes.len() - 1]);
        assert!(*sizes.last().unwrap() >= 32);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check_one(42, 8, |c| {
            for _ in 0..4 {
                a.push(c.rng.next_u64());
            }
        });
        check_one(42, 8, |c| {
            for _ in 0..4 {
                b.push(c.rng.next_u64());
            }
        });
        assert_eq!(a, b);
    }
}
