//! Model backends: the decode loop's view of "a thing that turns tokens
//! into logits", redesigned around per-request sessions with KV caches.
//!
//! [`ModelBackend::prefill`] absorbs a whole prompt into a fresh
//! [`Session`] (one O(T²)-attention pass) and returns the logits at its
//! last position; [`ModelBackend::decode_step`] then appends one token per
//! call at O(T) attention cost, reading and extending the session's KV
//! cache. [`ModelBackend::oracle_logits`] keeps the pre-cache decode path
//! — a full-prefix recompute per token — as the bitwise test oracle and
//! bench baseline (driven by `DecodeMode::Recompute`).
//!
//! All three built-in backends are artifact-free: the dense and low-rank
//! paths decode through the pure-Rust reference forward
//! (`model::forward`, `model::lowrank`), which the AOT artifacts are
//! validated against, so cached and recomputed logits can be compared
//! bit for bit. The PJRT artifacts stay on the batch-shaped paths
//! (calibration, refinement, eval), where round-tripping a KV cache
//! through host literals per step would dominate the win (see DESIGN.md).
//!
//! [`SyntheticBackend`] is a deterministic stand-in for tests and load
//! experiments: logits favor `(prev_token + 1) % vocab`, with optional
//! simulated per-step latency.

use crate::model::forward::{
    model_forward, model_forward_prefill, model_forward_step, KvCache,
};
use crate::model::lowrank::{
    model_lr_forward, model_lr_forward_prefill, model_lr_forward_step, BlockFactors,
};
use crate::model::{Config, FlatStore};
use anyhow::Result;
use std::time::Duration;

/// Per-request decode state: created by [`ModelBackend::prefill`],
/// advanced one token at a time by [`ModelBackend::decode_step`], freed by
/// dropping it (the engine drops the slot when a request retires).
pub struct Session {
    state: SessionState,
    /// artifact label of the backend that created this session; checked
    /// by `decode_step` so a session is never advanced by a different
    /// backend kind (which would silently corrupt its cache)
    backend: &'static str,
}

enum SessionState {
    Kv(KvCache),
    Synthetic { last: i32, len: usize },
}

impl Session {
    /// Tokens absorbed so far (prompt + generated) — derived from the
    /// backend state, so it can never drift out of sync with the cache.
    pub fn len(&self) -> usize {
        match &self.state {
            SessionState::Kv(c) => c.len,
            SessionState::Synthetic { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Label of the backend that created this session.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Cache-resident bytes held by this session's KV cache.
    pub fn kv_bytes(&self) -> usize {
        match &self.state {
            SessionState::Kv(c) => c.bytes(),
            SessionState::Synthetic { .. } => 0,
        }
    }
}

/// Result of absorbing a prompt: the session plus the logits row
/// ([vocab]) at the prompt's last position — the distribution the first
/// generated token is sampled from.
pub struct Prefill {
    pub session: Session,
    pub logits: Vec<f32>,
}

/// A forward-pass provider for the continuous-batching decode loop.
///
/// Contract: `prefill(p).logits`, and every subsequent `decode_step`
/// logits row, must be **bitwise identical** to `oracle_logits` over the
/// same token prefix (enforced by tests/kv_cache.rs and the serving
/// bench's pre-timing assert).
pub trait ModelBackend {
    /// Name of the decode path; used for logs and metrics labels.
    fn artifact(&self) -> &'static str;

    /// Absorb `tokens` (a full prompt, never empty) into a fresh session
    /// and return the logits row at its last position.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill>;

    /// Append one token to the session; returns the logits row [vocab]
    /// at the new last position, at O(len) attention cost.
    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>>;

    /// Full-prefix recompute oracle (the pre-KV-cache decode path):
    /// logits row [vocab] at the last position of `tokens`.
    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
}

/// A session may only be advanced by the backend kind that created it —
/// advancing e.g. a dense session with the low-rank step would silently
/// corrupt the cache and break the bitwise-oracle contract.
fn ensure_owner(session: &Session, artifact: &'static str) -> Result<()> {
    anyhow::ensure!(
        session.backend == artifact,
        "session belongs to backend '{}', not '{artifact}'",
        session.backend
    );
    Ok(())
}

/// Byte tokens arrive as i32 from the client surface; wrap defensively
/// into the model's vocab (mirrors the synthetic backend's behavior, and
/// keeps cached and oracle paths consistent by construction).
fn as_vocab_tokens(vocab: usize, tokens: &[i32]) -> Vec<u32> {
    tokens
        .iter()
        .map(|&t| t.rem_euclid(vocab as i32) as u32)
        .collect()
}

/// What the server is serving (the two built-in backend kinds).
pub enum ServedModel {
    Dense(FlatStore),
    Compressed(FlatStore, Vec<BlockFactors>),
}

impl ServedModel {
    /// Decode-path label of the backend this model builds.
    pub fn artifact(&self) -> &'static str {
        match self {
            ServedModel::Dense(_) => "dense_kv",
            ServedModel::Compressed(..) => "lowrank_kv",
        }
    }

    /// Build the KV-cached backend for this model.
    pub fn into_backend(self, cfg: &Config) -> Result<Box<dyn ModelBackend>> {
        Ok(match self {
            ServedModel::Dense(params) => {
                Box::new(DenseBackend::new(cfg.clone(), params))
            }
            ServedModel::Compressed(params, blocks) => {
                Box::new(CompressedBackend::new(cfg.clone(), params, blocks)?)
            }
        })
    }
}

/// Dense model through the KV-cached pure-Rust forward.
pub struct DenseBackend {
    cfg: Config,
    params: FlatStore,
}

impl DenseBackend {
    pub fn new(cfg: Config, params: FlatStore) -> DenseBackend {
        DenseBackend { cfg, params }
    }
}

impl ModelBackend for DenseBackend {
    fn artifact(&self) -> &'static str {
        "dense_kv"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let mut cache = KvCache::new(self.cfg.n_layers);
        let logits = model_forward_prefill(&self.cfg, &self.params, &mut cache, &toks);
        Ok(Prefill {
            session: Session {
                state: SessionState::Kv(cache),
                backend: self.artifact(),
            },
            logits,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let SessionState::Kv(cache) = &mut session.state else {
            anyhow::bail!("session does not belong to a KV-cached backend");
        };
        let tok = token.rem_euclid(self.cfg.vocab as i32) as u32;
        let logits = model_forward_step(&self.cfg, &self.params, cache, tok);
        Ok(logits)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let all = model_forward(&self.cfg, &self.params, &toks, toks.len());
        Ok(all[(toks.len() - 1) * self.cfg.vocab..].to_vec())
    }
}

/// Low-rank compressed model through the KV-cached pure-Rust forward;
/// shares the cached attention kernel with the dense path.
pub struct CompressedBackend {
    cfg: Config,
    params: FlatStore,
    blocks: Vec<BlockFactors>,
}

impl CompressedBackend {
    pub fn new(
        cfg: Config,
        params: FlatStore,
        blocks: Vec<BlockFactors>,
    ) -> Result<CompressedBackend> {
        anyhow::ensure!(
            blocks.len() == cfg.n_layers,
            "expected {} compressed blocks, got {}",
            cfg.n_layers,
            blocks.len()
        );
        Ok(CompressedBackend {
            cfg,
            params,
            blocks,
        })
    }
}

impl ModelBackend for CompressedBackend {
    fn artifact(&self) -> &'static str {
        "lowrank_kv"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let mut cache = KvCache::new(self.cfg.n_layers);
        let logits = model_lr_forward_prefill(
            &self.cfg,
            &self.params,
            &self.blocks,
            &mut cache,
            &toks,
        );
        Ok(Prefill {
            session: Session {
                state: SessionState::Kv(cache),
                backend: self.artifact(),
            },
            logits,
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let SessionState::Kv(cache) = &mut session.state else {
            anyhow::bail!("session does not belong to a KV-cached backend");
        };
        let tok = token.rem_euclid(self.cfg.vocab as i32) as u32;
        let logits =
            model_lr_forward_step(&self.cfg, &self.params, &self.blocks, cache, tok);
        Ok(logits)
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        let toks = as_vocab_tokens(self.cfg.vocab, tokens);
        let all =
            model_lr_forward(&self.cfg, &self.params, &self.blocks, &toks, toks.len());
        Ok(all[(toks.len() - 1) * self.cfg.vocab..].to_vec())
    }
}

/// Artifact-free backend for tests and load experiments: the logits after
/// any prefix deterministically favor `(last_token + 1) % vocab`, so
/// greedy decoding of prompt "a" yields "bcde…". `step_delay` emulates
/// model latency per prefill/decode/oracle call.
pub struct SyntheticBackend {
    cfg: Config,
    step_delay: Duration,
}

impl SyntheticBackend {
    pub fn new(cfg: Config) -> SyntheticBackend {
        SyntheticBackend {
            cfg,
            step_delay: Duration::ZERO,
        }
    }

    pub fn with_delay(cfg: Config, step_delay: Duration) -> SyntheticBackend {
        SyntheticBackend { cfg, step_delay }
    }

    fn logits_after(&self, last: i32) -> Vec<f32> {
        let v = self.cfg.vocab;
        let mut logits = vec![0f32; v];
        let prev = last.rem_euclid(v as i32) as usize;
        logits[(prev + 1) % v] = 8.0;
        logits
    }

    fn simulate_latency(&self) {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
    }
}

impl ModelBackend for SyntheticBackend {
    fn artifact(&self) -> &'static str {
        "synthetic"
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Prefill> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        self.simulate_latency();
        let last = *tokens.last().unwrap();
        Ok(Prefill {
            session: Session {
                state: SessionState::Synthetic {
                    last,
                    len: tokens.len(),
                },
                backend: self.artifact(),
            },
            logits: self.logits_after(last),
        })
    }

    fn decode_step(&mut self, session: &mut Session, token: i32) -> Result<Vec<f32>> {
        ensure_owner(session, self.artifact())?;
        let SessionState::Synthetic { last, len } = &mut session.state else {
            anyhow::bail!("session does not belong to the synthetic backend");
        };
        self.simulate_latency();
        *last = token;
        *len += 1;
        Ok(self.logits_after(token))
    }

    fn oracle_logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "oracle needs at least one token");
        self.simulate_latency();
        Ok(self.logits_after(*tokens.last().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    }

    #[test]
    fn synthetic_favors_successor_byte() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        let prompt = [b' ' as i32, b'a' as i32];
        let pf = be.prefill(&prompt).unwrap();
        assert_eq!(pf.session.len(), 2);
        assert!(!pf.session.is_empty());
        assert_eq!(pf.session.kv_bytes(), 0);
        assert_eq!(argmax(&pf.logits), b'b' as usize);
    }

    #[test]
    fn synthetic_decode_step_tracks_last_token() {
        let cfg = Config::builtin("tiny").unwrap();
        let mut be = SyntheticBackend::new(cfg);
        let Prefill { mut session, .. } = be.prefill(&[b'a' as i32]).unwrap();
        let logits = be.decode_step(&mut session, b'b' as i32).unwrap();
        assert_eq!(argmax(&logits), b'c' as usize);
        assert_eq!(session.len(), 2);
        // the oracle over the same prefix agrees bitwise
        let want = be.oracle_logits(&[b'a' as i32, b'b' as i32]).unwrap();
        assert_eq!(logits, want);
    }

    #[test]
    fn dense_session_holds_cache_bytes() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        let mut be = DenseBackend::new(cfg.clone(), params);
        let prompt: Vec<i32> = "abc".bytes().map(|b| b as i32).collect();
        let Prefill { mut session, .. } = be.prefill(&prompt).unwrap();
        let bytes_after_prefill = session.kv_bytes();
        assert_eq!(
            bytes_after_prefill,
            3 * cfg.n_layers * 2 * cfg.d_model * 4
        );
        be.decode_step(&mut session, b'd' as i32).unwrap();
        assert_eq!(session.len(), 4);
        assert!(session.kv_bytes() > bytes_after_prefill);
    }

    #[test]
    fn foreign_session_is_rejected() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(2));
        let blocks = vec![crate::model::lowrank::BlockFactors::zeros(&cfg); cfg.n_layers];
        let mut synth = SyntheticBackend::new(cfg.clone());
        let mut dense = DenseBackend::new(cfg.clone(), params.clone());
        let mut compressed = CompressedBackend::new(cfg, params, blocks).unwrap();

        // synthetic session into a KV backend
        let Prefill { mut session, .. } = synth.prefill(&[b'a' as i32]).unwrap();
        assert!(dense.decode_step(&mut session, b'b' as i32).is_err());

        // dense session into the low-rank backend (both are Kv-state, so
        // only the owner tag catches the mix)
        let Prefill { mut session, .. } = dense.prefill(&[b'a' as i32]).unwrap();
        assert_eq!(session.backend(), "dense_kv");
        assert!(compressed.decode_step(&mut session, b'b' as i32).is_err());
        // and the rightful owner still advances it fine afterwards
        assert!(dense.decode_step(&mut session, b'b' as i32).is_ok());
    }

    #[test]
    fn served_model_artifact_names() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(1));
        assert_eq!(ServedModel::Dense(params.clone()).artifact(), "dense_kv");
        assert_eq!(
            ServedModel::Compressed(params, Vec::new()).artifact(),
            "lowrank_kv"
        );
    }

    #[test]
    fn compressed_backend_rejects_wrong_block_count() {
        let cfg = Config::builtin("tiny").unwrap();
        let params = init_params(&cfg, &mut Rng::new(3));
        assert!(CompressedBackend::new(cfg, params, Vec::new()).is_err());
    }
}
