// aasvd-lint: path=src/serve/kv_pool.rs

use std::collections::HashMap;

pub fn trie_children() -> HashMap<Vec<u32>, usize> {
    HashMap::new()
}
