"""AOT pipeline tests: HLO text round-trip + manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def test_to_hlo_text_roundtrips_through_xla_parser():
    """The text we emit must parse back into an XlaComputation."""
    from jax._src.lib import xla_client as xc

    def fn(x):
        return (x @ x.T + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # round-trip: parse HLO text back (the same path the xla crate uses)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_layout_json_offsets_are_contiguous():
    cfg = M.CONFIGS["tiny"]
    lay = aot.layout_json(M.param_specs(cfg))
    off = 0
    for ent in lay:
        assert ent["offset"] == off
        off += int(np.prod(ent["shape"]))
    assert off == M.total_size(M.param_specs(cfg))


def test_kernel_entry_points_shapes():
    cfg = M.CONFIGS["tiny"]
    eps = aot.kernel_entry_points(cfg)
    assert set(eps) == {
        "cov_accum_d", "cov_accum_ff", "cross_cov_accum_d",
        "cross_cov_accum_ff", "lowrank_apply", "attention_head",
    }
    fn, args = eps["cov_accum_d"]
    assert tuple(args[0].shape) == (cfg.d_model, cfg.d_model)
    assert args[1].shape[0] == aot.COV_CHUNK
    # entry point is actually executable
    out = fn(jnp.zeros(args[0].shape), jnp.ones(args[1].shape))[0]
    np.testing.assert_allclose(
        np.asarray(out), np.full(args[0].shape, aot.COV_CHUNK),
        rtol=1e-4, atol=1e-4)


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_manifest_matches_model_layouts():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        man = json.load(f)
    for name, entry in man["configs"].items():
        cfg = M.CONFIGS[name]
        assert entry["dims"]["d_model"] == cfg.d_model
        assert entry["param_layout"][-1]["name"] == "lm_head"
        psize = (entry["param_layout"][-1]["offset"]
                 + cfg.vocab * cfg.d_model)
        assert psize == M.total_size(M.param_specs(cfg))
        for aname, art in entry["artifacts"].items():
            f = os.path.join(os.path.dirname(path), art["file"])
            assert os.path.exists(f), f"{aname} artifact missing"
            assert art["inputs"] and art["outputs"]
