//! Approximate-equality assertions for numeric tests.

/// Assert elementwise |a-b| <= tol * (1 + max(|a|,|b|)) — mixed abs/rel.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "index {i}: {x} vs {y} (diff {:.3e}, tol {:.3e})",
            (x - y).abs(),
            tol * scale
        );
    }
}

/// f32 variant.
pub fn assert_close_f32(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "index {i}: {x} vs {y} (diff {:.3e}, tol {:.3e})",
            (x - y).abs(),
            tol * scale
        );
    }
}

/// Relative Frobenius distance ‖a−b‖/‖b‖ (slices viewed as flat vectors).
pub fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_passes() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9);
    }

    #[test]
    #[should_panic]
    fn far_fails() {
        assert_close(&[1.0], &[1.1], 1e-9);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }
}
